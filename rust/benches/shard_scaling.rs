//! Shard scaling: how the sharded ordering engine spreads work that a
//! single runtime serializes.
//!
//! Two measurements per shard count (1, 2, 4), total worker threads held
//! fixed so only the *shape* changes:
//!
//! - **multi-component latency** — one request whose graph has many
//!   comparable connected components (`matgen::multi_component`); with
//!   shards the components order concurrently, so latency should drop
//!   toward the largest component's cost.
//! - **burst throughput** — a `submit_all` burst of connected requests
//!   drained by several schedulers; with shards concurrent requests stop
//!   serializing behind one runtime.
//!
//! Writes the JSON trajectory file `BENCH_shard_scaling.json` (override
//! with `PARAMD_BENCH_SHARD_OUT`; default lands in the repository root
//! when run via `cargo bench` from `rust/`).
//!
//! Knobs: `PARAMD_THREADS` (default 8), `PARAMD_REPS` (default 6), or
//! `--smoke` for a one-pass CI run.

#[path = "bench_common/mod.rs"]
#[allow(dead_code)] // shared helper module; this bench uses a subset
mod bench_common;

use paramd::coordinator::{Method, OrderRequest, Service, ShardSpec};
use paramd::matgen::{mesh2d, multi_component};
use paramd::util::timer::Timer;

fn paramd_req(g: paramd::graph::csr::SymGraph) -> OrderRequest {
    OrderRequest {
        matrix: None,
        pattern: Some(g),
        method: Method::ParAmd {
            threads: 4,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    }
}

fn main() {
    bench_common::banner(
        "Shard scaling — component decomposition + multi-runtime routing",
        "ROADMAP sharding PR; not a paper table",
    );
    let smoke = std::env::args().any(|a| a == "--smoke");
    let total_threads = bench_common::threads().max(4);
    let reps: usize = if smoke {
        1
    } else {
        std::env::var("PARAMD_REPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(6)
    };
    // 8 comparable mesh-like components; small in smoke mode.
    let comp_sizes: Vec<usize> = if smoke {
        vec![400, 650, 900, 500]
    } else {
        vec![2500, 4000, 6400, 3200]
    };
    let g = multi_component(8, &comp_sizes);
    let burst: usize = if smoke { 8 } else { 24 };
    let side = if smoke { 24 } else { 48 };

    println!(
        "graph: n={} in 8 components | burst: {burst} connected requests (mesh2d {side}x{side})",
        g.n
    );
    println!(
        "{:<8} {:>14} {:>12} {:>10}",
        "shards", "multi-comp(s)", "burst req/s", "busy_peak"
    );

    let mut rows = Vec::new();
    for shards in [1usize, 2, 4] {
        let per_shard = (total_threads / shards).max(1);
        let svc = Service::new(2)
            .with_shard_spec(ShardSpec::new(shards, per_shard, per_shard))
            .with_scheduler_threads(shards.max(2))
            // Shape scaling, not caching, is under test: repeated
            // requests must genuinely re-order on every config.
            .with_result_cache(0);

        // (a) one multi-component request, repeated.
        let req = paramd_req(g.clone());
        svc.order(&req); // warm the arenas
        let t = Timer::new();
        for _ in 0..reps {
            let rep = svc.order(&req);
            assert_eq!(rep.perm.len(), g.n);
        }
        let multi_secs = t.secs() / reps as f64;

        // (b) a submit_all burst of connected requests.
        let reqs: Vec<OrderRequest> = (0..burst).map(|_| paramd_req(mesh2d(side, side))).collect();
        let t = Timer::new();
        let tickets = svc.submit_all(reqs);
        for ticket in tickets {
            assert!(!ticket.wait().perm.is_empty());
        }
        let burst_rps = burst as f64 / t.secs();

        let m = svc.metrics();
        println!(
            "{:<8} {:>14.4} {:>12.2} {:>10}",
            shards, multi_secs, burst_rps, m.shards.busy_peak
        );
        rows.push(format!(
            "    {{\"shards\": {shards}, \"threads_per_shard\": {per_shard}, \
             \"multi_component_secs\": {multi_secs:.6}, \"burst_requests_per_sec\": \
             {burst_rps:.3}, \"busy_peak\": {}}}",
            m.shards.busy_peak
        ));
    }

    let out = std::env::var("PARAMD_BENCH_SHARD_OUT")
        .unwrap_or_else(|_| "../BENCH_shard_scaling.json".into());
    let json = format!(
        "{{\n  \"bench\": \"shard_scaling\",\n  \"status\": \"measured\",\n  \
         \"total_threads\": {total_threads},\n  \"graph_n\": {},\n  \
         \"components\": 8,\n  \"burst_requests\": {burst},\n  \"configs\": [\n{}\n  ]\n}}\n",
        g.n,
        rows.join(",\n")
    );
    std::fs::write(&out, &json).expect("write bench json");
    println!("\nwrote {out}");
}
