//! The concurrent quotient graph (§3.3.1 of the paper).
//!
//! All node arrays are plain atomics accessed with `Relaxed` ordering;
//! the round barriers in the driver provide the cross-thread
//! happens-before edges. Within a round, the distance-2 independence of
//! the pivots guarantees (see DESIGN.md §6):
//!
//! - every variable/element *written* during elimination is owned by
//!   exactly one pivot, hence one thread;
//! - elements *read* by several threads (an element shared between two
//!   pivots' periphery) are never concurrently absorbed or relocated;
//! - the only benign races are reads of `nv`/`degree`/`state` of nodes
//!   being merged by their owner — every observable value keeps the
//!   AMD degrees approximate upper bounds.
//!
//! Storage follows SuiteSparse's single-`iw` scheme with elbow room; the
//! elbow cursor `pfree` is claimed with a **single `fetch_add` per pivot**
//! after the pivot's connection updates are collected in thread-local
//! scratch, exactly as §3.3.1 prescribes. On exhaustion the pivot is
//! deferred and a stop-the-world GC runs at the next round boundary.

use std::sync::atomic::{AtomicBool, AtomicI32, AtomicU8, AtomicUsize, Ordering::Relaxed};

use crate::graph::csr::SymGraph;

/// Node states, stored as `u8` atomics.
pub const ST_VAR: u8 = 0;
pub const ST_ELEM: u8 = 1;
pub const ST_DEAD_VAR: u8 = 2;
pub const ST_DEAD_ELEM: u8 = 3;

/// The shared quotient graph.
pub struct SharedGraph {
    pub n: usize,
    pub iw: Vec<AtomicI32>,
    pub pe: Vec<AtomicUsize>,
    pub len: Vec<AtomicI32>,
    pub elen: Vec<AtomicI32>,
    /// Supervariable size (vars); pivot block size (elements); 0 when dead.
    pub nv: Vec<AtomicI32>,
    /// Approximate external degree (vars) / weighted `|L_e|` (elements).
    pub degree: Vec<AtomicI32>,
    pub state: Vec<AtomicU8>,
    pub parent: Vec<AtomicI32>,
    /// Elbow cursor: next free slot in `iw`.
    pub pfree: AtomicUsize,
    /// Columns eliminated so far.
    pub nel: AtomicUsize,
    /// Set when a thread failed to claim elbow space; triggers GC.
    pub gc_requested: AtomicBool,
}

impl SharedGraph {
    /// Build from a symmetric pattern with `elbow × nnz` extra space
    /// (the paper's empirical 1.5 default lives in the ParAMD config).
    pub fn new(g: &SymGraph, elbow: f64) -> Self {
        let n = g.n;
        let nnz = g.nnz();
        let iwlen = nnz + (nnz as f64 * elbow) as usize + 16;
        let iw: Vec<AtomicI32> = (0..iwlen)
            .map(|i| AtomicI32::new(if i < nnz { g.colind[i] } else { 0 }))
            .collect();
        SharedGraph {
            n,
            iw,
            pe: (0..n).map(|v| AtomicUsize::new(g.rowptr[v])).collect(),
            len: (0..n).map(|v| AtomicI32::new(g.degree(v) as i32)).collect(),
            elen: (0..n).map(|_| AtomicI32::new(0)).collect(),
            nv: (0..n).map(|_| AtomicI32::new(1)).collect(),
            degree: (0..n).map(|v| AtomicI32::new(g.degree(v) as i32)).collect(),
            state: (0..n).map(|_| AtomicU8::new(ST_VAR)).collect(),
            parent: (0..n).map(|_| AtomicI32::new(-1)).collect(),
            pfree: AtomicUsize::new(nnz),
            nel: AtomicUsize::new(0),
            gc_requested: AtomicBool::new(false),
        }
    }

    // -- relaxed accessors (all cross-thread sync comes from barriers) ---

    #[inline]
    pub fn st(&self, i: usize) -> u8 {
        self.state[i].load(Relaxed)
    }
    #[inline]
    pub fn set_st(&self, i: usize, s: u8) {
        self.state[i].store(s, Relaxed);
    }
    #[inline]
    pub fn iw_at(&self, p: usize) -> i32 {
        self.iw[p].load(Relaxed)
    }
    #[inline]
    pub fn iw_set(&self, p: usize, v: i32) {
        self.iw[p].store(v, Relaxed);
    }
    #[inline]
    pub fn nv_of(&self, i: usize) -> i32 {
        self.nv[i].load(Relaxed)
    }
    #[inline]
    pub fn deg_of(&self, i: usize) -> i32 {
        self.degree[i].load(Relaxed)
    }
    #[inline]
    pub fn pe_of(&self, i: usize) -> usize {
        self.pe[i].load(Relaxed)
    }
    #[inline]
    pub fn len_of(&self, i: usize) -> i32 {
        self.len[i].load(Relaxed)
    }
    #[inline]
    pub fn elen_of(&self, i: usize) -> i32 {
        self.elen[i].load(Relaxed)
    }

    /// Claim `need` slots of elbow room with one `fetch_add` (§3.3.1).
    /// Returns the start offset, or `None` when exhausted (the caller
    /// defers its pivot and requests a GC).
    pub fn claim(&self, need: usize) -> Option<usize> {
        let off = self.pfree.fetch_add(need, Relaxed);
        if off + need <= self.iw.len() {
            Some(off)
        } else {
            // Roll the cursor back best-effort; concurrent claims make this
            // approximate, which is fine — GC recomputes it exactly.
            self.pfree.fetch_sub(need, Relaxed);
            self.gc_requested.store(true, Relaxed);
            None
        }
    }

    /// Stop-the-world garbage collection: compact all live lists to the
    /// front of `iw`, pruning dead entries and refreshing element weights.
    /// Must be called while every other thread is parked at a barrier.
    pub fn garbage_collect_exclusive(&self) {
        let mut order: Vec<u32> = (0..self.n as u32)
            .filter(|&i| {
                let s = self.st(i as usize);
                (s == ST_VAR || s == ST_ELEM) && self.len_of(i as usize) > 0
            })
            .collect();
        order.sort_by_key(|&i| self.pe_of(i as usize));
        let mut dst = 0usize;
        for &iu in &order {
            let i = iu as usize;
            let src = self.pe_of(i);
            debug_assert!(src >= dst);
            if self.st(i) == ST_ELEM {
                let mut weight = 0i32;
                let mut kept = 0usize;
                for k in 0..self.len_of(i) as usize {
                    let v = self.iw_at(src + k);
                    if self.st(v as usize) == ST_VAR {
                        self.iw_set(dst + kept, v);
                        kept += 1;
                        weight += self.nv_of(v as usize);
                    }
                }
                self.pe[i].store(dst, Relaxed);
                self.len[i].store(kept as i32, Relaxed);
                self.degree[i].store(weight, Relaxed);
                dst += kept;
            } else {
                let mut kept_e = 0usize;
                for k in 0..self.elen_of(i) as usize {
                    let e = self.iw_at(src + k);
                    if self.st(e as usize) == ST_ELEM {
                        self.iw_set(dst + kept_e, e);
                        kept_e += 1;
                    }
                }
                let mut kept = kept_e;
                for k in self.elen_of(i) as usize..self.len_of(i) as usize {
                    let v = self.iw_at(src + k);
                    if self.st(v as usize) == ST_VAR {
                        self.iw_set(dst + kept, v);
                        kept += 1;
                    }
                }
                self.pe[i].store(dst, Relaxed);
                self.elen[i].store(kept_e as i32, Relaxed);
                self.len[i].store(kept as i32, Relaxed);
                dst += kept;
            }
        }
        self.pfree.store(dst, Relaxed);
        self.gc_requested.store(false, Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::mesh2d;

    #[test]
    fn construction_mirrors_graph() {
        let g = mesh2d(4, 4);
        let sg = SharedGraph::new(&g, 1.5);
        assert_eq!(sg.n, 16);
        assert_eq!(sg.pfree.load(Relaxed), g.nnz());
        for v in 0..g.n {
            assert_eq!(sg.len_of(v) as usize, g.degree(v));
            assert_eq!(sg.deg_of(v) as usize, g.degree(v));
            assert_eq!(sg.st(v), ST_VAR);
            let p = sg.pe_of(v);
            let nbrs: Vec<i32> = (0..g.degree(v)).map(|k| sg.iw_at(p + k)).collect();
            assert_eq!(nbrs.as_slice(), g.neighbors(v));
        }
    }

    #[test]
    fn claim_and_exhaust() {
        let g = mesh2d(3, 3);
        let sg = SharedGraph::new(&g, 0.0);
        let avail = sg.iw.len() - sg.pfree.load(Relaxed);
        assert!(sg.claim(avail).is_some());
        assert!(sg.claim(1).is_none());
        assert!(sg.gc_requested.load(Relaxed));
    }

    #[test]
    fn gc_compacts_and_preserves_live_lists() {
        let g = mesh2d(4, 4);
        let sg = SharedGraph::new(&g, 1.0);
        // Kill vertex 0 and re-point vertex 1's list into the elbow.
        sg.set_st(0, ST_DEAD_VAR);
        sg.len[0].store(0, Relaxed);
        let off = sg.claim(2).unwrap();
        sg.iw_set(off, 2);
        sg.iw_set(off + 1, 5);
        sg.pe[1].store(off, Relaxed);
        sg.len[1].store(2, Relaxed);
        sg.elen[1].store(0, Relaxed);
        let before: Vec<i32> = (0..2).map(|k| sg.iw_at(sg.pe_of(1) + k)).collect();
        sg.garbage_collect_exclusive();
        let after: Vec<i32> = (0..sg.len_of(1) as usize)
            .map(|k| sg.iw_at(sg.pe_of(1) + k))
            .collect();
        assert_eq!(before, after);
        assert!(sg.pfree.load(Relaxed) < off + 2, "gc must reclaim space");
        assert!(!sg.gc_requested.load(Relaxed));
    }
}
