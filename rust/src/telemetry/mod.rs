//! End-to-end request telemetry: the per-request flight recorder and the
//! fixed-footprint metrics exposition.
//!
//! Two halves, both bounded in memory no matter how many requests flow:
//!
//! - [`trace`] — the **flight recorder**: a [`RequestTrace`] of
//!   timestamped spans (queued → preprocess → cc-split → reduce →
//!   cache-probe → route → per-shard dispatch → elimination → rereduce
//!   sweeps → stitch → fill) carried with every pipeline ticket and
//!   renderable as Chrome trace-event JSON
//!   ([`RequestTrace::to_chrome_json`]) for Perfetto / `about:tracing`.
//! - [`export`] — pull-based **exposition** of the coordinator's
//!   [`Metrics`](crate::coordinator::Metrics) snapshot: Prometheus text
//!   format ([`export::prometheus`]) and a JSON document
//!   ([`export::json_snapshot`]). Latency series behind these renderers
//!   are log-bucketed [`LogHistogram`](crate::util::stats::LogHistogram)s,
//!   so exposition cost and storage are constant in the request count.
//!
//! The serve CLI wires both up: `--metrics-every N` prints the
//! Prometheus page every N completions, `--trace-dir D` (with
//! `--trace-slow-ms`) dumps slow requests' Chrome traces into `D`.

pub mod export;
pub mod trace;

pub use trace::{shard_lane, RequestTrace, SpanRecord, LANE_ENGINE, LANE_PIPELINE};

/// Structural JSON validation (no deserialization): checks that `s` is
/// exactly one well-formed JSON value. Used by tests and the CI smoke to
/// guarantee the hand-rolled renderers ([`RequestTrace::to_chrome_json`],
/// [`export::json_snapshot`]) always emit parseable documents.
pub fn validate_json(s: &str) -> Result<(), String> {
    let b = s.as_bytes();
    let mut i = 0usize;
    skip_ws(b, &mut i);
    parse_value(b, &mut i)?;
    skip_ws(b, &mut i);
    if i != b.len() {
        return Err(format!("trailing garbage at byte {i}"));
    }
    Ok(())
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn parse_value(b: &[u8], i: &mut usize) -> Result<(), String> {
    match b.get(*i) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => parse_object(b, i),
        Some(b'[') => parse_array(b, i),
        Some(b'"') => parse_string(b, i),
        Some(b't') => parse_lit(b, i, "true"),
        Some(b'f') => parse_lit(b, i, "false"),
        Some(b'n') => parse_lit(b, i, "null"),
        Some(c) if *c == b'-' || c.is_ascii_digit() => parse_number(b, i),
        Some(c) => Err(format!("unexpected byte {:?} at {}", *c as char, *i)),
    }
}

fn parse_lit(b: &[u8], i: &mut usize, lit: &str) -> Result<(), String> {
    if b[*i..].starts_with(lit.as_bytes()) {
        *i += lit.len();
        Ok(())
    } else {
        Err(format!("bad literal at byte {}", *i))
    }
}

fn parse_string(b: &[u8], i: &mut usize) -> Result<(), String> {
    *i += 1; // opening quote
    while let Some(&c) = b.get(*i) {
        match c {
            b'"' => {
                *i += 1;
                return Ok(());
            }
            b'\\' => {
                *i += 2; // escape + escaped byte (\uXXXX hex is benign)
            }
            _ => *i += 1,
        }
    }
    Err("unterminated string".into())
}

fn parse_number(b: &[u8], i: &mut usize) -> Result<(), String> {
    let start = *i;
    if b.get(*i) == Some(&b'-') {
        *i += 1;
    }
    let digits = |b: &[u8], i: &mut usize| {
        let s = *i;
        while matches!(b.get(*i), Some(c) if c.is_ascii_digit()) {
            *i += 1;
        }
        *i > s
    };
    if !digits(b, i) {
        return Err(format!("bad number at byte {start}"));
    }
    if b.get(*i) == Some(&b'.') {
        *i += 1;
        if !digits(b, i) {
            return Err(format!("bad fraction at byte {start}"));
        }
    }
    if matches!(b.get(*i), Some(b'e' | b'E')) {
        *i += 1;
        if matches!(b.get(*i), Some(b'+' | b'-')) {
            *i += 1;
        }
        if !digits(b, i) {
            return Err(format!("bad exponent at byte {start}"));
        }
    }
    Ok(())
}

fn parse_array(b: &[u8], i: &mut usize) -> Result<(), String> {
    *i += 1; // '['
    skip_ws(b, i);
    if b.get(*i) == Some(&b']') {
        *i += 1;
        return Ok(());
    }
    loop {
        parse_value(b, i)?;
        skip_ws(b, i);
        match b.get(*i) {
            Some(b',') => {
                *i += 1;
                skip_ws(b, i);
            }
            Some(b']') => {
                *i += 1;
                return Ok(());
            }
            _ => return Err(format!("expected ',' or ']' at byte {}", *i)),
        }
    }
}

fn parse_object(b: &[u8], i: &mut usize) -> Result<(), String> {
    *i += 1; // '{'
    skip_ws(b, i);
    if b.get(*i) == Some(&b'}') {
        *i += 1;
        return Ok(());
    }
    loop {
        if b.get(*i) != Some(&b'"') {
            return Err(format!("expected object key at byte {}", *i));
        }
        parse_string(b, i)?;
        skip_ws(b, i);
        if b.get(*i) != Some(&b':') {
            return Err(format!("expected ':' at byte {}", *i));
        }
        *i += 1;
        skip_ws(b, i);
        parse_value(b, i)?;
        skip_ws(b, i);
        match b.get(*i) {
            Some(b',') => {
                *i += 1;
                skip_ws(b, i);
            }
            Some(b'}') => {
                *i += 1;
                return Ok(());
            }
            _ => return Err(format!("expected ',' or '}}' at byte {}", *i)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_json_accepts_well_formed_documents() {
        for ok in [
            "{}",
            "[]",
            "0",
            "-1.5e-3",
            "\"x\"",
            "true",
            " {\"a\": [1, 2.5, {\"b\": null}], \"c\": \"d\\\"e\"} ",
        ] {
            validate_json(ok).unwrap_or_else(|e| panic!("{ok:?} rejected: {e}"));
        }
    }

    #[test]
    fn validate_json_rejects_malformed_documents() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "{\"a\" 1}",
            "{} {}",
            "1.",
            "\"unterminated",
            "{a: 1}",
        ] {
            assert!(validate_json(bad).is_err(), "{bad:?} must be rejected");
        }
    }
}
