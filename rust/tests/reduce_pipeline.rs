//! Reduction-layer integration: reduce→order→expand must always yield a
//! valid permutation, stay within the fill band of the unreduced path on
//! the whole matgen suite, and flow end-to-end through the sharded
//! service with exact per-rule metrics.

use std::sync::atomic::AtomicBool;

use paramd::coordinator::{Method, OrderRequest, Service};
use paramd::graph::csr::SymGraph;
use paramd::graph::perm::is_valid_perm;
use paramd::matgen::{self, twin_heavy, with_dense_rows, Scale};
use paramd::ordering::paramd::arena::ParAmdArena;
use paramd::ordering::paramd::runtime::OrderingRuntime;
use paramd::ordering::paramd::ParAmd;
use paramd::ordering::reduce::{reduce, ReduceConfig};
use paramd::ordering::Ordering as _;
use paramd::prop::{arb_graph, forall, Config};
use paramd::symbolic::fill_in;

/// reduce → weighted kernel ordering → expand, single-threaded
/// (deterministic).
fn reduced_order(g: &SymGraph, cfg: &ReduceConfig) -> Vec<i32> {
    let plan = reduce(g, cfg);
    let rt = OrderingRuntime::new(1);
    let mut arena = ParAmdArena::new();
    let cancel = AtomicBool::new(false);
    let kernel_perm = if plan.kernel.n == 0 {
        Vec::new()
    } else {
        ParAmd::new(1)
            .order_into_cancellable_weighted(
                &rt,
                &mut arena,
                &plan.kernel,
                Some(&plan.weights),
                &cancel,
            )
            .expect("uncancelled run completes")
            .perm
            .clone()
    };
    plan.expand(&kernel_perm)
}

#[test]
fn property_reduce_order_expand_is_always_a_valid_permutation() {
    forall(
        Config {
            cases: 30,
            seed: 0x2ED0CE,
        },
        |rng| arb_graph(rng, 150),
        |g| {
            let perm = reduced_order(g, &ReduceConfig::default());
            if perm.len() != g.n {
                return Err(format!("perm length {} != n {}", perm.len(), g.n));
            }
            if !is_valid_perm(&perm) {
                return Err("expanded perm is not a permutation".into());
            }
            Ok(())
        },
    );
}

#[test]
fn property_reduced_fill_stays_in_band_on_arbitrary_graphs() {
    forall(
        Config {
            cases: 15,
            seed: 0xF111ED,
        },
        |rng| arb_graph(rng, 120),
        |g| {
            let reduced = fill_in(g, &reduced_order(g, &ReduceConfig::default())) as f64;
            let plain = fill_in(g, &ParAmd::new(1).order(g).perm) as f64;
            // Leaf stripping is exact and twin merging is what AMD does
            // internally; dense postponement may trade a little fill for
            // round count. Keep a generous band at toy scale.
            if reduced > plain * 1.25 + 60.0 {
                return Err(format!("fill {reduced} vs unreduced {plain}"));
            }
            Ok(())
        },
    );
}

#[test]
fn matgen_suite_fill_within_1_05x_of_the_unreduced_path() {
    // The acceptance criterion: over the whole suite, the reduced
    // pipeline stays within 1.05× of the unreduced fill (plus a tiny
    // absolute slack for near-zero fills).
    for e in matgen::suite() {
        let g = (e.gen)(Scale::Tiny);
        let reduced = fill_in(&g, &reduced_order(&g, &ReduceConfig::default())) as f64;
        let plain = fill_in(&g, &ParAmd::new(1).order(&g).perm) as f64;
        assert!(
            reduced <= plain * 1.05 + 50.0,
            "{}: reduced fill {reduced} exceeds 1.05x of unreduced {plain}",
            e.name
        );
    }
}

#[test]
fn twin_heavy_service_request_reduces_and_stays_in_the_fill_band() {
    let g = twin_heavy(480, 8);
    let req = |pattern: SymGraph| OrderRequest {
        matrix: None,
        pattern: Some(pattern),
        method: Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: true,
    };
    let on = Service::new(1);
    let rep_on = on.order(&req(g.clone()));
    let off = Service::new(1).with_reduction(false);
    let rep_off = off.order(&req(g.clone()));

    assert!(is_valid_perm(&rep_on.perm));
    assert!(is_valid_perm(&rep_off.perm));
    let (f_on, f_off) = (
        rep_on.fill_in.unwrap() as f64,
        rep_off.fill_in.unwrap() as f64,
    );
    assert!(
        f_on <= f_off * 1.05 + 50.0,
        "reduced fill {f_on} vs unreduced {f_off}"
    );

    let m = on.metrics();
    assert_eq!(m.shards.reduced_jobs, 1);
    assert_eq!(
        m.shards.twins_merged as usize,
        480 - 480 / 8,
        "8-fold compression merges 7/8 of the vertices"
    );
    assert_eq!(off.metrics().shards.reduced_jobs, 0);
}

#[test]
fn dense_row_service_request_postpones_and_orders_validly() {
    let g = with_dense_rows(900, 450, 3);
    let svc = Service::new(1).with_dense_alpha(2.0); // threshold = 2·√903 ≈ 60
    let rep = svc.order(&OrderRequest {
        matrix: None,
        pattern: Some(g.clone()),
        method: Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    });
    assert!(is_valid_perm(&rep.perm));
    // The three injected rows must be ordered last (the dense tail).
    let tail: Vec<i32> = rep.perm[g.n - 3..].to_vec();
    let mut tail_sorted = tail.clone();
    tail_sorted.sort_unstable();
    assert_eq!(
        tail_sorted,
        vec![900, 901, 902],
        "dense rows must land at the permutation tail"
    );
    let m = svc.metrics();
    assert_eq!(m.shards.dense_postponed, 3);
}

#[test]
fn pendant_tails_reduce_through_the_decomposed_path() {
    // Components with path tails: leaves strip per component, the
    // stitched reply covers every vertex, and the per-rule counters add
    // up across component jobs.
    let g = matgen::multi_component(4, &[60, 90]);
    let svc = Service::new(1).with_shards(2).with_shard_threads(1);
    let rep = svc.order(&OrderRequest {
        matrix: None,
        pattern: Some(g.clone()),
        method: Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    });
    assert!(is_valid_perm(&rep.perm));
    assert_eq!(rep.perm.len(), g.n);
    let m = svc.metrics();
    assert!(
        m.shards.leaves_stripped > 0,
        "path tails must strip as leaves"
    );
    assert_eq!(m.shards.components, 4);
}

#[test]
fn reduced_ordering_is_deterministic_across_repeats() {
    let g = twin_heavy(300, 5);
    let svc = Service::new(1);
    let req = OrderRequest {
        matrix: None,
        pattern: Some(g),
        method: Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    };
    let first = svc.order(&req);
    for _ in 0..2 {
        assert_eq!(svc.order(&req).perm, first.perm, "warm repeats must bit-match");
    }
}
