//! Concurrent approximate-degree lists — Algorithm 3.1 of the paper,
//! verbatim.
//!
//! Each thread owns `n` doubly-linked degree lists plus a `loc` array
//! recording which local list a variable sits in and a local `lamd`
//! (minimum approximate degree among locally maintained variables). A
//! single shared `affinity` array records which thread holds the freshest
//! information for each variable; stale entries in other threads' lists
//! are reclaimed lazily during [`ThreadLists::get`] traversals.
//!
//! Distance-2 independence guarantees a variable is updated by at most one
//! thread per elimination round, so `Insert`/`Remove` for a given `v` never
//! race; the only cross-thread traffic is the `affinity` flag.

use std::sync::atomic::{AtomicI32, Ordering::Relaxed};

/// Shared affinity flags: `affinity[v] = tid` of the owner of the freshest
/// degree info for `v`, or -1 when `v` has been removed (eliminated).
///
/// The mid-elimination sweep ([`crate::ordering::reduce::live`]) uses the
/// same -1 protocol for the twins it merges and the rows it re-postpones:
/// stale entries left in thread-local degree lists are reclaimed lazily by
/// the next [`ThreadLists::get`] traversal, exactly like eliminated
/// variables.
pub struct Affinity {
    flags: Vec<AtomicI32>,
}

impl Affinity {
    pub fn new(n: usize) -> Self {
        Self {
            flags: (0..n).map(|_| AtomicI32::new(-1)).collect(),
        }
    }

    #[inline]
    pub fn get(&self, v: usize) -> i32 {
        self.flags[v].load(Relaxed)
    }

    #[inline]
    pub fn set(&self, v: usize, tid: i32) {
        self.flags[v].store(tid, Relaxed);
    }

    /// Re-initialize for a graph of `n` vertices, growing monotonically and
    /// reusing storage when the graph fits. Returns 1 if storage grew.
    pub fn reset(&mut self, n: usize) -> u32 {
        let mut grew = 0;
        if self.flags.len() < n {
            self.flags.resize_with(n, || AtomicI32::new(-1));
            grew = 1;
        }
        for f in &self.flags[..n] {
            f.store(-1, Relaxed);
        }
        grew
    }
}

/// One thread's degree lists (Algorithm 3.1 state for a single `tid`).
///
/// Degrees are bucketed up to `dmax` inclusive. Ordinarily `dmax == n`
/// (an external degree never reaches `n`); a **weighted** run — seed
/// supervariables with `nv > 1` from the reduction layer — sets `dmax`
/// to the total column weight, since weighted degrees can exceed the
/// kernel's vertex count. `dmax` doubles as the "no live variable"
/// sentinel [`Self::lamd`] returns.
pub struct ThreadLists {
    pub tid: i32,
    n: usize,
    /// Largest representable degree (and the empty-lists sentinel).
    dmax: usize,
    /// `dhead[d]` -> first variable in the local degree-`d` list.
    dhead: Vec<i32>,
    dnext: Vec<i32>,
    dprev: Vec<i32>,
    /// Local degree list each variable belongs to, -1 if none (the paper's
    /// `loc` array).
    loc: Vec<i32>,
    /// Local minimum approximate degree (the paper's `lamd`).
    lamd: usize,
}

impl ThreadLists {
    pub fn new(tid: usize, n: usize) -> Self {
        Self {
            tid: tid as i32,
            n,
            dmax: n,
            dhead: vec![-1; n + 1],
            dnext: vec![-1; n],
            dprev: vec![-1; n],
            loc: vec![-1; n],
            lamd: n,
        }
    }

    /// Re-initialize for a graph of `n` vertices whose degrees are
    /// bounded by `dmax` (pass `n` for an unweighted run), growing
    /// monotonically and reusing list storage when the graph fits (the
    /// arena's warm path). Returns 1 if storage grew.
    pub fn reset(&mut self, n: usize, dmax: usize) -> u32 {
        let dmax = dmax.max(n);
        let mut grew = 0;
        if self.dnext.len() < n {
            self.dnext.resize(n, -1);
            self.dprev.resize(n, -1);
            self.loc.resize(n, -1);
            grew = 1;
        }
        if self.dhead.len() < dmax + 1 {
            self.dhead.resize(dmax + 1, -1);
            grew = 1;
        }
        self.n = n;
        self.dmax = dmax;
        self.lamd = dmax;
        for x in self.dhead[..=dmax].iter_mut() {
            *x = -1;
        }
        for x in self.dnext[..n].iter_mut() {
            *x = -1;
        }
        for x in self.dprev[..n].iter_mut() {
            *x = -1;
        }
        for x in self.loc[..n].iter_mut() {
            *x = -1;
        }
        grew
    }

    /// Algorithm 3.1 `REMOVE(tid, v)` — O(1): invalidate every copy of `v`
    /// by clearing the affinity; physical entries are reclaimed lazily.
    pub fn remove(&mut self, aff: &Affinity, v: usize) {
        debug_assert!(v < self.n);
        aff.set(v, -1);
    }

    /// Algorithm 3.1 `INSERT(tid, v, deg)`.
    pub fn insert(&mut self, aff: &Affinity, v: usize, deg: usize) {
        let deg = deg.min(self.dmax);
        if self.loc[v] != -1 {
            self.unlink(v, self.loc[v] as usize);
        }
        // Link v at the head of dlist[deg].
        let h = self.dhead[deg];
        self.dnext[v] = h;
        self.dprev[v] = -1;
        if h != -1 {
            self.dprev[h as usize] = v as i32;
        }
        self.dhead[deg] = v as i32;
        self.loc[v] = deg as i32;
        aff.set(v, self.tid);
        self.lamd = self.lamd.min(deg);
    }

    fn unlink(&mut self, v: usize, d: usize) {
        let prev = self.dprev[v];
        let next = self.dnext[v];
        if prev != -1 {
            self.dnext[prev as usize] = next;
        } else {
            debug_assert_eq!(self.dhead[d], v as i32);
            self.dhead[d] = next;
        }
        if next != -1 {
            self.dprev[next as usize] = prev;
        }
        self.dnext[v] = -1;
        self.dprev[v] = -1;
    }

    /// Algorithm 3.1 `GET(tid, deg)`: collect the live entries of the local
    /// degree-`deg` list into `out`, lazily unlinking entries whose
    /// affinity moved to another thread (or -1).
    pub fn get(&mut self, aff: &Affinity, deg: usize, out: &mut Vec<i32>) {
        let mut v = self.dhead[deg.min(self.dmax)];
        while v != -1 {
            let vu = v as usize;
            let next = self.dnext[vu];
            if aff.get(vu) != self.tid {
                self.unlink(vu, deg);
                self.loc[vu] = -1;
            } else {
                out.push(v);
            }
            v = next;
        }
    }

    /// Algorithm 3.1 `LAMD(tid)`: advance past empty/stale lists and return
    /// the local minimum approximate degree (`dmax` when empty).
    ///
    /// Allocation-free: walks each list only until the first *live* entry,
    /// purging stale ones on the way (they would be purged by the next
    /// `get` anyway) — EXPERIMENTS.md §Perf change #3.
    pub fn lamd(&mut self, aff: &Affinity) -> usize {
        while self.lamd < self.dmax {
            let mut v = self.dhead[self.lamd];
            let mut found = false;
            while v != -1 {
                let vu = v as usize;
                let next = self.dnext[vu];
                if aff.get(vu) == self.tid {
                    found = true;
                    break;
                }
                self.unlink(vu, self.lamd);
                self.loc[vu] = -1;
                v = next;
            }
            if found {
                return self.lamd;
            }
            self.lamd += 1;
        }
        self.dmax
    }

    /// Number of live entries currently linked (test helper; O(n)).
    #[cfg(test)]
    pub fn live_count(&self, aff: &Affinity) -> usize {
        (0..=self.dmax)
            .map(|d| {
                let mut c = 0;
                let mut v = self.dhead[d];
                while v != -1 {
                    if aff.get(v as usize) == self.tid {
                        c += 1;
                    }
                    v = self.dnext[v as usize];
                }
                c
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insert_get_roundtrip() {
        let aff = Affinity::new(10);
        let mut l = ThreadLists::new(0, 10);
        l.insert(&aff, 3, 5);
        l.insert(&aff, 4, 5);
        l.insert(&aff, 7, 2);
        let mut out = vec![];
        l.get(&aff, 5, &mut out);
        out.sort();
        assert_eq!(out, vec![3, 4]);
        assert_eq!(l.lamd(&aff), 2);
    }

    #[test]
    fn reinsert_moves_between_lists() {
        let aff = Affinity::new(10);
        let mut l = ThreadLists::new(0, 10);
        l.insert(&aff, 3, 5);
        l.insert(&aff, 3, 2); // degree update moves it
        let mut out = vec![];
        l.get(&aff, 5, &mut out);
        assert!(out.is_empty());
        l.get(&aff, 2, &mut out);
        assert_eq!(out, vec![3]);
    }

    #[test]
    fn remove_invalidates_without_unlinking() {
        let aff = Affinity::new(10);
        let mut l = ThreadLists::new(0, 10);
        l.insert(&aff, 3, 5);
        l.remove(&aff, 3);
        let mut out = vec![];
        l.get(&aff, 5, &mut out);
        assert!(out.is_empty());
        assert_eq!(l.live_count(&aff), 0);
    }

    #[test]
    fn stale_entries_reclaimed_across_threads() {
        // Thread 0 inserts v, thread 1 takes it over; thread 0's entry is
        // stale and must be purged by get().
        let aff = Affinity::new(10);
        let mut t0 = ThreadLists::new(0, 10);
        let mut t1 = ThreadLists::new(1, 10);
        t0.insert(&aff, 5, 4);
        t1.insert(&aff, 5, 7); // fresher info on thread 1
        let mut out = vec![];
        t0.get(&aff, 4, &mut out);
        assert!(out.is_empty(), "stale entry must not be returned");
        t1.get(&aff, 7, &mut out);
        assert_eq!(out, vec![5]);
    }

    #[test]
    fn lamd_skips_empty_lists() {
        let aff = Affinity::new(20);
        let mut l = ThreadLists::new(0, 20);
        assert_eq!(l.lamd(&aff), 20); // empty
        l.insert(&aff, 1, 15);
        assert_eq!(l.lamd(&aff), 15);
        l.insert(&aff, 2, 3);
        assert_eq!(l.lamd(&aff), 3);
        l.remove(&aff, 2);
        assert_eq!(l.lamd(&aff), 15);
    }

    #[test]
    fn lamd_is_monotone_after_removals() {
        let aff = Affinity::new(8);
        let mut l = ThreadLists::new(0, 8);
        l.insert(&aff, 0, 1);
        l.insert(&aff, 1, 4);
        l.remove(&aff, 0);
        assert_eq!(l.lamd(&aff), 4);
        l.remove(&aff, 1);
        assert_eq!(l.lamd(&aff), 8);
    }

    #[test]
    fn reset_reuses_storage_and_clears_state() {
        let mut aff = Affinity::new(10);
        let mut l = ThreadLists::new(0, 10);
        l.insert(&aff, 3, 5);
        l.insert(&aff, 7, 2);
        // Same-size reset: no growth, all lists empty again.
        assert_eq!(l.reset(10, 10), 0);
        assert_eq!(aff.reset(10), 0);
        assert_eq!(l.lamd(&aff), 10);
        let mut out = vec![];
        l.get(&aff, 5, &mut out);
        assert!(out.is_empty());
        // Shrink then regrow: monotonic storage, correct behavior at both.
        assert_eq!(l.reset(4, 4), 0);
        assert_eq!(aff.reset(4), 0);
        l.insert(&aff, 2, 3);
        assert_eq!(l.lamd(&aff), 3);
        assert_eq!(l.reset(16, 16), 1);
        assert_eq!(aff.reset(16), 1);
        l.insert(&aff, 15, 12);
        assert_eq!(l.lamd(&aff), 12);
    }

    #[test]
    fn weighted_degree_bound_extends_the_buckets() {
        // dmax > n: weighted runs store degrees past the vertex count
        // and the empty sentinel moves to dmax.
        let mut aff = Affinity::new(4);
        let mut l = ThreadLists::new(0, 4);
        assert_eq!(l.reset(4, 100), 1, "wider dhead must grow");
        assert_eq!(aff.reset(4), 0);
        assert_eq!(l.lamd(&aff), 100, "empty sentinel is dmax");
        l.insert(&aff, 2, 57); // beyond n, within dmax: kept exactly
        assert_eq!(l.lamd(&aff), 57);
        let mut out = vec![];
        l.get(&aff, 57, &mut out);
        assert_eq!(out, vec![2]);
        l.remove(&aff, 2);
        assert_eq!(l.lamd(&aff), 100);
    }

    #[test]
    fn degree_clamped_to_n() {
        let aff = Affinity::new(4);
        let mut l = ThreadLists::new(0, 4);
        l.insert(&aff, 2, 1000); // clamped into bucket n
        let mut out = vec![];
        l.get(&aff, 1000, &mut out);
        assert_eq!(out, vec![2]);
    }
}
