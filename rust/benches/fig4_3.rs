//! Figure 4.3: the mult × lim parameter study on the worst-scaling
//! (mini_nd24k) and best-scaling (mini_nlpkkt) matrices — core AMD time,
//! distance-2 selection time, and #fill-ins over the grid.
//!
//! Times are the cost-model critical path (1-core testbed); the paper's
//! qualitative findings to look for: too-small mult starves parallelism,
//! too-large mult wrecks quality; the optimum sits near mult≈1.1–1.2 with
//! a moderate lim.

#[path = "bench_common/mod.rs"]
mod bench_common;

use paramd::bench_util::{fmt_sci, Table};
use paramd::matgen;
use paramd::ordering::paramd::{cost, ParAmd};
use paramd::symbolic::fill_in;
use paramd::util::timer::Timer;

fn main() {
    let t = bench_common::threads();
    bench_common::banner("Figure 4.3 — mult × lim sweep", "paper §4.5 Fig 4.3");
    let mults = [1.0, 1.05, 1.1, 1.2, 1.4];
    let lims = [64usize, 128, 512, 2048];
    for name in ["mini_nd24k", "mini_nlpkkt"] {
        let e = matgen::suite_entry(name).unwrap();
        let g = (e.gen)(bench_common::scale());
        println!("--- {name} ({t} threads) ---");
        let mut table = Table::new(&[
            "mult", "lim_total", "select cpu (s)", "core cpu (s)", "modeled (s)", "#fill-ins",
        ]);
        // Calibrate the work→time constant once per matrix.
        let mut work_per_sec = 0.0;
        {
            let (_, d) = ParAmd::new(1).order_detailed(&g);
            let total: u64 = d.round_work.iter().flatten().map(|w| w.select + w.elim).sum();
            let secs: f64 = d.select_secs.iter().sum::<f64>() + d.elim_secs.iter().sum::<f64>();
            work_per_sec = total as f64 / secs.max(1e-9);
        }
        for &mult in &mults {
            for &lim in &lims {
                let timer = Timer::new();
                let (r, d) = ParAmd::new(t)
                    .with_mult(mult)
                    .with_lim_total(lim)
                    .order_detailed(&g);
                let _wall = timer.secs();
                let fill = fill_in(&g, &r.perm) as f64;
                table.row(vec![
                    format!("{mult:.2}"),
                    format!("{lim}"),
                    format!("{:.3}", d.select_secs.iter().sum::<f64>()),
                    format!("{:.3}", d.elim_secs.iter().sum::<f64>()),
                    format!("{:.3}", cost::modeled_time(&d.round_work, work_per_sec, 5e-6)),
                    fmt_sci(fill),
                ]);
            }
        }
        table.print();
        println!();
    }
    println!("paper: optimum near mult=1.2/lim=128; defaults mult=1.1, lim=8192/threads.");
}
