//! ND×ParAMD hybrid vs single-wide-shard ordering of ONE huge connected
//! mesh — the workload where component decomposition finds nothing to
//! parallelize across.
//!
//! Two engines, equal total worker threads:
//!
//! - **baseline** — one wide shard: the connected request runs as a
//!   single borrowed job (parallelism only *inside* elimination steps).
//! - **hybrid** — four shards with the hybrid planner on: the mesh is
//!   cut into independent subdomains that order concurrently across the
//!   shards, separators last.
//!
//! The acceptance bar is hybrid wall-clock below the baseline with
//! fill-in within 1.15× of pure ParAMD. Writes the JSON trajectory file
//! `BENCH_nd_hybrid.json` (override with `PARAMD_BENCH_HYBRID_OUT`;
//! default lands in the repository root when run via `cargo bench` from
//! `rust/`).
//!
//! Knobs: `PARAMD_THREADS` (default 8), `PARAMD_REPS` (default 3), or
//! `--smoke` for a quick CI pass (full scale is a 450×450 mesh —
//! 202,500 vertices, the >= 200k acceptance scenario).

#[path = "bench_common/mod.rs"]
#[allow(dead_code)] // shared helper module; this bench uses a subset
mod bench_common;

use paramd::matgen::mesh2d;
use paramd::ordering::hybrid::HybridConfig;
use paramd::ordering::paramd::ParAmd;
use paramd::ordering::shard::{ShardEngine, ShardSpec};
use paramd::symbolic::fill_in;
use paramd::util::timer::Timer;

fn main() {
    bench_common::banner(
        "ND x ParAMD hybrid — one huge connected mesh across shards",
        "ISSUE 6 perf subsystem; not a paper table",
    );
    let smoke = std::env::args().any(|a| a == "--smoke");
    let threads = bench_common::threads();
    let reps: usize = if smoke {
        1
    } else {
        std::env::var("PARAMD_REPS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(3)
    };
    let side = if smoke { 200 } else { 450 };
    let g = mesh2d(side, side);
    let cfg = ParAmd::new(threads);
    let hybrid = HybridConfig {
        enabled: true,
        partition_threshold: 10_000,
        recursion_depth: 2,
        balance_factor: 1.3,
    };

    // Baseline: one wide shard, hybrid off — the whole mesh is a single
    // job. The cache is disabled on both engines so every rep measures
    // real ordering work.
    let baseline = ShardEngine::new(ShardSpec::new(1, threads, 1));
    baseline.result_cache().set_budget(0);
    baseline.order(&g, cfg); // warm the arenas
    let t = Timer::new();
    let mut base_perm = Vec::new();
    for _ in 0..reps {
        base_perm = baseline.order(&g, cfg).perm;
    }
    let base_secs = t.secs() / reps as f64;
    let base_fill = fill_in(&g, &base_perm);
    drop(baseline);

    // Hybrid: the same total thread count spread over four shards.
    let per_shard = (threads / 4).max(1);
    let engine = ShardEngine::new(ShardSpec::uniform(4, per_shard));
    engine.result_cache().set_budget(0);
    engine.set_hybrid(hybrid);
    engine.order(&g, cfg); // warm the arenas + one partition
    let t = Timer::new();
    let mut hyb_perm = Vec::new();
    for _ in 0..reps {
        hyb_perm = engine.order(&g, cfg).perm;
    }
    let hyb_secs = t.secs() / reps as f64;
    let hyb_fill = fill_in(&g, &hyb_perm);

    let m = engine.metrics();
    let speedup = base_secs / hyb_secs.max(1e-12);
    let fill_ratio = hyb_fill as f64 / base_fill.max(1) as f64;
    println!("{:<22} {:>12} {:>14}", "engine", "latency(s)", "fill-in");
    println!(
        "{:<22} {:>12.4} {:>14.3e}",
        "1 wide shard", base_secs, base_fill as f64
    );
    println!(
        "{:<22} {:>12.4} {:>14.3e}",
        "hybrid (4 shards)", hyb_secs, hyb_fill as f64
    );
    println!(
        "speedup={speedup:.2}x fill_ratio={fill_ratio:.3} subdomains={} separators={} \
         sep_frac={:.4} partition={:.4}s busy_peak={}",
        m.subdomains / m.hybrid_requests.max(1),
        m.separators / m.hybrid_requests.max(1),
        m.separator_frac(),
        m.partition_secs,
        m.busy_peak
    );
    if hyb_secs >= base_secs {
        eprintln!("WARNING: hybrid wall-clock not below the single-wide-shard baseline");
    }
    if fill_ratio > 1.15 {
        eprintln!("WARNING: hybrid fill ratio {fill_ratio:.3} above the 1.15x acceptance bar");
    }

    let out = std::env::var("PARAMD_BENCH_HYBRID_OUT")
        .unwrap_or_else(|_| "../BENCH_nd_hybrid.json".into());
    let json = format!(
        "{{\n  \"bench\": \"nd_hybrid\",\n  \"status\": \"measured\",\n  \
         \"threads\": {threads},\n  \"reps\": {reps},\n  \
         \"workload\": \"mesh2d({side}x{side}) = {} vertices, connected\",\n  \
         \"acceptance\": \"hybrid wall-clock < 1-wide-shard baseline; fill <= 1.15x\",\n  \
         \"hybrid\": \"threshold=10000 depth=2 balance=1.3 over 4x{per_shard}-thread shards\",\n  \
         \"baseline_secs\": {base_secs:.6},\n  \"hybrid_secs\": {hyb_secs:.6},\n  \
         \"speedup\": {speedup:.3},\n  \"fill_ratio\": {fill_ratio:.4},\n  \
         \"subdomains\": {},\n  \"separator_frac\": {:.6},\n  \
         \"partition_secs\": {:.6},\n  \"busy_peak\": {}\n}}\n",
        g.n,
        m.subdomains / m.hybrid_requests.max(1),
        m.separator_frac(),
        m.partition_secs,
        m.busy_peak
    );
    std::fs::write(&out, &json).expect("write bench json");
    println!("\nwrote {out}");
}
