//! Plumbing of the async ordering pipeline: the bounded MPMC job queue
//! the service enqueues onto, and the [`Ticket`] a submitter holds while
//! its request flows through the scheduler.
//!
//! See the [`coordinator`](crate::coordinator) module docs for the
//! request lifecycle; this module only defines the mechanisms.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::request::{Lane, OrderReply, OrderRequest};
use crate::telemetry::RequestTrace;
use crate::util::lock_unpoisoned;
use crate::util::timer::Timer;

/// Why a non-blocking enqueue did not happen, carrying the item back.
pub(crate) enum TryPushError<T> {
    /// The queue is at capacity — admission control turns this into a
    /// structured [`OrderError::Rejected`] shed.
    Full(T),
    /// The queue is closed (service tearing down).
    Closed(T),
}

/// A bounded MPMC queue with two priority lanes. `push` blocks while the
/// queue is full — this is the pipeline's backpressure: submitters stall
/// instead of the service buffering unboundedly ([`Self::try_push`] is
/// the non-blocking admission-control variant that hands the item back).
/// `pop` serves the interactive lane first, FIFO within each lane, and
/// blocks while empty, returning `None` once the queue is closed *and*
/// drained, so consumers finish every accepted job before exiting.
pub(crate) struct BoundedQueue<T> {
    state: Mutex<QueueState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
}

struct QueueState<T> {
    /// Indexed by [`Lane::index`]: interactive, then batch.
    lanes: [VecDeque<T>; 2],
    cap: usize,
    closed: bool,
}

impl<T> QueueState<T> {
    fn len(&self) -> usize {
        self.lanes.iter().map(VecDeque::len).sum()
    }
}

impl<T> BoundedQueue<T> {
    pub(crate) fn new(cap: usize) -> Self {
        Self {
            state: Mutex::new(QueueState {
                lanes: [VecDeque::new(), VecDeque::new()],
                cap: cap.max(1),
                closed: false,
            }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
        }
    }

    /// Enqueue on the batch lane, blocking while full. Returns the
    /// resulting depth, or the item back if the queue has been closed.
    pub(crate) fn push(&self, item: T) -> Result<usize, T> {
        self.push_lane(item, Lane::Batch)
    }

    /// Enqueue on `lane`, blocking while full. The capacity bound is
    /// shared across lanes (priority changes *service order*, not how
    /// much the service buffers).
    pub(crate) fn push_lane(&self, item: T, lane: Lane) -> Result<usize, T> {
        // Poisoned locks recover via `into_inner`: the queue state is a
        // pair of deques plus plain flags, never left mid-mutation by a
        // panicking holder.
        let mut st = lock_unpoisoned(self.state.lock());
        loop {
            if st.closed {
                return Err(item);
            }
            if st.len() < st.cap {
                st.lanes[lane.index()].push_back(item);
                let depth = st.len();
                drop(st);
                self.not_empty.notify_one();
                return Ok(depth);
            }
            st = lock_unpoisoned(self.not_full.wait(st));
        }
    }

    /// Non-blocking enqueue on `lane`: either the item is in (returning
    /// the depth) or it comes straight back with the reason — the shed
    /// path never stalls the caller.
    pub(crate) fn try_push(&self, item: T, lane: Lane) -> Result<usize, TryPushError<T>> {
        let mut st = lock_unpoisoned(self.state.lock());
        if st.closed {
            return Err(TryPushError::Closed(item));
        }
        if st.len() >= st.cap {
            return Err(TryPushError::Full(item));
        }
        st.lanes[lane.index()].push_back(item);
        let depth = st.len();
        drop(st);
        self.not_empty.notify_one();
        Ok(depth)
    }

    /// Enqueue a whole batch on one lane, blocking while full. The queue
    /// is locked once per chunk of available slots rather than once per
    /// item — the batched-submission fast path — and consumers are woken
    /// after each chunk so they can drain while the tail of the batch
    /// waits. Returns the final depth, or the unpushed remainder if the
    /// queue closed mid-batch.
    pub(crate) fn push_all(&self, items: Vec<T>, lane: Lane) -> Result<usize, Vec<T>> {
        let mut it = items.into_iter();
        let mut st = lock_unpoisoned(self.state.lock());
        loop {
            if st.closed {
                return Err(it.collect());
            }
            let mut pushed = false;
            while st.len() < st.cap {
                match it.next() {
                    Some(x) => {
                        st.lanes[lane.index()].push_back(x);
                        pushed = true;
                    }
                    None => {
                        let depth = st.len();
                        drop(st);
                        if pushed {
                            self.not_empty.notify_all();
                        }
                        return Ok(depth);
                    }
                }
            }
            // Queue full with batch remaining: wake the consumers, then
            // wait for them to free slots.
            self.not_empty.notify_all();
            st = lock_unpoisoned(self.not_full.wait(st));
        }
    }

    /// Dequeue, blocking while empty; `None` once closed and drained.
    /// The interactive lane always overtakes the batch lane.
    pub(crate) fn pop(&self) -> Option<T> {
        let mut st = lock_unpoisoned(self.state.lock());
        loop {
            if let Some(item) = st.lanes[Lane::Interactive.index()]
                .pop_front()
                .or_else(|| st.lanes[Lane::Batch.index()].pop_front())
            {
                drop(st);
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = lock_unpoisoned(self.not_empty.wait(st));
        }
    }

    pub(crate) fn len(&self) -> usize {
        lock_unpoisoned(self.state.lock()).len()
    }

    pub(crate) fn capacity(&self) -> usize {
        lock_unpoisoned(self.state.lock()).cap
    }

    pub(crate) fn set_capacity(&self, cap: usize) {
        lock_unpoisoned(self.state.lock()).cap = cap.max(1);
        self.not_full.notify_all();
    }

    /// Stop accepting pushes and wake everyone; queued items still drain
    /// through `pop`.
    pub(crate) fn close(&self) {
        lock_unpoisoned(self.state.lock()).closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }
}

/// Where a queued request's body lives.
pub(crate) enum RequestSlot {
    /// Submitted by value through `Service::submit`.
    Owned(OrderRequest),
    /// Lifetime-erased borrow from a blocking `Service::order` caller,
    /// which waits on the ticket before releasing the borrow.
    Borrowed(BorrowedRequest),
}

pub(crate) struct BorrowedRequest(*const OrderRequest);

// SAFETY: the pointer crosses to the scheduler thread, but the pointee
// is owned by an `order()` caller that blocks on the ticket until the
// scheduler's last access (fulfill/fail happens strictly after). Shared
// `&OrderRequest` access from another thread additionally requires
// `OrderRequest: Sync`, enforced at compile time below so a future
// interior-mutability field can't silently introduce a data race.
unsafe impl Send for BorrowedRequest {}

const _: () = {
    const fn assert_sync<T: Sync>() {}
    assert_sync::<OrderRequest>()
};

impl BorrowedRequest {
    /// SAFETY: the caller must outlive every scheduler access, which
    /// `Service::order` guarantees by blocking on the ticket.
    pub(crate) unsafe fn new(req: &OrderRequest) -> Self {
        Self(req as *const OrderRequest)
    }
}

impl RequestSlot {
    pub(crate) fn get(&self) -> &OrderRequest {
        match self {
            RequestSlot::Owned(req) => req,
            // SAFETY: see `BorrowedRequest::new`.
            RequestSlot::Borrowed(b) => unsafe { &*b.0 },
        }
    }
}

/// One queued request: its body, the submitter's ticket, the queue
/// stopwatch (wait-vs-service latency split), and the admission-time
/// scheduling attributes (lane + request-carried deadline).
pub(crate) struct PipelineJob {
    pub(crate) req: RequestSlot,
    pub(crate) ticket: Arc<TicketInner>,
    pub(crate) queued: Timer,
    pub(crate) lane: Lane,
    pub(crate) deadline: Option<Instant>,
}

/// Why an ordering request did not produce a reply — the typed half of
/// [`Ticket::wait_result`]. Every abandonment path in the pipeline maps
/// to exactly one variant; none of them panic the waiter.
#[derive(Clone, Debug, PartialEq)]
pub enum OrderError {
    /// Processing failed: the ordering panicked (contained by the
    /// scheduler/dispatcher `catch_unwind`) or the service shut down
    /// with the request still queued. The message says which.
    Failed(String),
    /// The request was cancelled — ticket dropped, [`Ticket::cancel`]
    /// called, or a [`Ticket::wait_deadline`] expiry withdrew interest.
    Cancelled,
    /// The request-carried deadline expired before completion; doomed
    /// work was abandoned at a stage boundary or between elimination
    /// rounds.
    DeadlineExceeded,
    /// Shed at admission (`try_submit`): the service is over its
    /// in-flight budget, the queue is full, or the caller is out of
    /// quota tokens. Back off for roughly the hint before retrying.
    Rejected {
        /// How long the service suggests waiting before a retry.
        retry_after_hint: Duration,
    },
}

impl std::fmt::Display for OrderError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            // Bare message: `wait()` prefixes "order ticket failed: ",
            // preserving the historical panic text verbatim.
            OrderError::Failed(why) => f.write_str(why),
            OrderError::Cancelled => f.write_str("request cancelled"),
            OrderError::DeadlineExceeded => f.write_str("request deadline exceeded"),
            OrderError::Rejected { retry_after_hint } => write!(
                f,
                "request rejected by admission control; retry after ~{}ms",
                retry_after_hint.as_millis()
            ),
        }
    }
}

impl std::error::Error for OrderError {}

#[derive(Debug)]
enum TicketState {
    Pending,
    Ready(OrderReply),
    Taken,
    Failed(OrderError),
}

/// A batch-wide completion queue: one condvar shared by every ticket of
/// a [`wait_all`](crate::coordinator::Service::wait_all) batch. Tickets
/// push their index here as they resolve, so the harvester wakes once
/// per completion instead of once per ticket condvar — the wakeup-count
/// win for large bursts.
pub(crate) struct WaitBatch {
    ready: Mutex<VecDeque<usize>>,
    cv: Condvar,
}

impl WaitBatch {
    pub(crate) fn new() -> Arc<Self> {
        Arc::new(Self {
            ready: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
        })
    }

    fn notify(&self, index: usize) {
        // Plain index queue: recover from poison rather than losing the
        // whole batch harvest to one panicked resolver.
        lock_unpoisoned(self.ready.lock()).push_back(index);
        self.cv.notify_all();
    }

    /// Block until some ticket of the batch resolved; returns its index
    /// in completion order.
    pub(crate) fn wait_one(&self) -> usize {
        let mut ready = lock_unpoisoned(self.ready.lock());
        loop {
            if let Some(i) = ready.pop_front() {
                return i;
            }
            ready = lock_unpoisoned(self.cv.wait(ready));
        }
    }
}

/// State + watcher registration behind one lock: a resolver and an
/// attacher can never race into a lost or doubled batch notification.
struct TicketSt {
    state: TicketState,
    /// Batch completion queue to poke on resolution, with this ticket's
    /// index in the batch.
    watcher: Option<(Arc<WaitBatch>, usize)>,
}

/// Shared half of a ticket: the scheduler resolves it, the submitter
/// waits on it, and the cancel flag flows down into the ordering rounds.
pub(crate) struct TicketInner {
    st: Mutex<TicketSt>,
    cv: Condvar,
    cancel: AtomicBool,
    /// Set by the deadline reaper (or a stage-boundary check) when the
    /// request-carried deadline expired: distinguishes a deadline abort
    /// from an ordinary cancellation when the engine unwinds.
    deadline_fired: AtomicBool,
    /// The request's flight recorder — created with the ticket (its
    /// epoch is submit time) and shared down the scheduler, engine, and
    /// shard dispatchers.
    trace: Arc<RequestTrace>,
}

impl TicketInner {
    fn resolve(&self, to: TicketState) {
        // Ticket state is a plain enum swap; recover from poison so one
        // panicked waiter can't wedge resolution for the scheduler.
        let mut st = lock_unpoisoned(self.st.lock());
        if matches!(st.state, TicketState::Pending) {
            st.state = to;
            let watcher = st.watcher.take();
            drop(st);
            self.cv.notify_all();
            if let Some((batch, index)) = watcher {
                batch.notify(index);
            }
        }
    }

    pub(crate) fn fulfill(&self, reply: OrderReply) {
        self.resolve(TicketState::Ready(reply));
    }

    pub(crate) fn fail(&self, why: impl Into<String>) {
        self.resolve(TicketState::Failed(OrderError::Failed(why.into())));
    }

    /// Resolve with a typed error (cancellation, deadline, rejection).
    pub(crate) fn fail_with(&self, err: OrderError) {
        self.resolve(TicketState::Failed(err));
    }

    pub(crate) fn is_cancelled(&self) -> bool {
        self.cancel.load(Relaxed)
    }

    /// Mark the request-carried deadline as expired and abort the work:
    /// sets the same cancel flag the elimination rounds already poll, so
    /// an in-flight kernel stops at its next round boundary, while the
    /// `deadline_fired` bit routes the outcome to
    /// [`OrderError::DeadlineExceeded`] instead of `Cancelled`.
    pub(crate) fn expire_deadline(&self) {
        self.deadline_fired.store(true, Relaxed);
        self.cancel.store(true, Relaxed);
    }

    pub(crate) fn deadline_fired(&self) -> bool {
        self.deadline_fired.load(Relaxed)
    }

    /// Whether the ticket is still unresolved (reaper housekeeping).
    pub(crate) fn is_pending(&self) -> bool {
        matches!(lock_unpoisoned(self.st.lock()).state, TicketState::Pending)
    }

    /// The flag threaded into `ParAmd::order_into_cancellable`.
    pub(crate) fn cancel_flag(&self) -> &AtomicBool {
        &self.cancel
    }

    /// The request's flight recorder.
    pub(crate) fn trace(&self) -> &Arc<RequestTrace> {
        &self.trace
    }
}

/// Returned by [`Ticket::wait_deadline`] when the reply did not arrive
/// in time; the request has been cancelled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WaitTimeout;

impl std::fmt::Display for WaitTimeout {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("order ticket deadline expired; request cancelled")
    }
}

impl std::error::Error for WaitTimeout {}

/// A claim on one submitted ordering request. [`Ticket::wait`] blocks
/// for the reply ([`Ticket::wait_deadline`] bounds the wait and cancels
/// on expiry); [`Ticket::try_get`] polls. **Dropping a ticket without
/// consuming it cancels the request**: queued jobs are skipped outright
/// and a running ParAMD job aborts at its next round boundary, freeing
/// the shared pool for live requests.
pub struct Ticket {
    inner: Arc<TicketInner>,
}

impl Ticket {
    pub(crate) fn new() -> (Ticket, Arc<TicketInner>) {
        let inner = Arc::new(TicketInner {
            st: Mutex::new(TicketSt {
                state: TicketState::Pending,
                watcher: None,
            }),
            cv: Condvar::new(),
            cancel: AtomicBool::new(false),
            deadline_fired: AtomicBool::new(false),
            trace: Arc::new(RequestTrace::new()),
        });
        (
            Ticket {
                inner: Arc::clone(&inner),
            },
            inner,
        )
    }

    /// Register this ticket with a batch completion queue under `index`.
    /// Returns `false` (without registering) when the ticket has already
    /// resolved — the caller harvests it immediately instead.
    pub(crate) fn attach_watcher(&self, batch: &Arc<WaitBatch>, index: usize) -> bool {
        let mut st = lock_unpoisoned(self.inner.st.lock());
        if matches!(st.state, TicketState::Pending) {
            st.watcher = Some((Arc::clone(batch), index));
            true
        } else {
            false
        }
    }

    /// Non-blocking take of a resolved outcome: `Ok(reply)` or the
    /// failure message. `None` while pending. A double take reports as
    /// `Err` rather than panicking, so a batch harvest
    /// ([`crate::coordinator::Service::wait_all`]) never loses the other
    /// outcomes to one already-consumed ticket.
    pub(crate) fn take_result(&self) -> Option<Result<OrderReply, String>> {
        self.take_result_typed().map(|r| r.map_err(|e| e.to_string()))
    }

    /// [`Self::take_result`] with the typed error preserved.
    pub(crate) fn take_result_typed(&self) -> Option<Result<OrderReply, OrderError>> {
        let mut st = lock_unpoisoned(self.inner.st.lock());
        match std::mem::replace(&mut st.state, TicketState::Taken) {
            TicketState::Ready(reply) => Some(Ok(reply)),
            TicketState::Failed(why) => Some(Err(why)),
            TicketState::Pending => {
                st.state = TicketState::Pending;
                None
            }
            TicketState::Taken => {
                Some(Err(OrderError::Failed("order ticket already consumed".into())))
            }
        }
    }

    /// Block until the request resolves and return the typed outcome:
    /// the reply, or exactly why the pipeline abandoned it
    /// ([`OrderError::Failed`] / `Cancelled` / `DeadlineExceeded` /
    /// `Rejected`). Never panics — this is the API services should wait
    /// on; [`Self::wait`] is the panicking shim kept for the synchronous
    /// `order()` contract.
    pub fn wait_result(self) -> Result<OrderReply, OrderError> {
        let mut st = lock_unpoisoned(self.inner.st.lock());
        loop {
            match std::mem::replace(&mut st.state, TicketState::Taken) {
                TicketState::Ready(reply) => return Ok(reply),
                TicketState::Pending => {
                    st.state = TicketState::Pending;
                    st = lock_unpoisoned(self.inner.cv.wait(st));
                }
                TicketState::Failed(why) => return Err(why),
                TicketState::Taken => {
                    return Err(OrderError::Failed("order ticket already consumed".into()))
                }
            }
        }
    }

    /// Block until the reply arrives and take it.
    ///
    /// Panics if the pipeline abandoned the request (service shut down,
    /// the request was cancelled, or the ordering panicked) — the same
    /// contract the synchronous `order()` shim has always had. Prefer
    /// [`Self::wait_result`] for a typed, non-panicking outcome.
    pub fn wait(self) -> OrderReply {
        match self.wait_result() {
            Ok(reply) => reply,
            Err(OrderError::Failed(why)) if why == "order ticket already consumed" => {
                panic!("order ticket already consumed")
            }
            Err(why) => panic!("order ticket failed: {why}"),
        }
    }

    /// [`Self::wait`] with a deadline: block at most `timeout` for the
    /// reply. **On expiry the request is cancelled** (the consumed
    /// ticket withdraws interest exactly like a drop: a queued job is
    /// skipped, a running ParAMD job aborts at its next round boundary)
    /// and `Err(WaitTimeout)` is returned — the caller's tail latency is
    /// bounded and the shared pools are not left grinding on an answer
    /// nobody wants. A reply that lands right at the deadline is still
    /// taken and returned.
    ///
    /// Panics like [`Self::wait`] if the pipeline abandoned the request
    /// before the deadline.
    pub fn wait_deadline(self, timeout: Duration) -> Result<OrderReply, WaitTimeout> {
        let deadline = Instant::now() + timeout;
        let mut st = lock_unpoisoned(self.inner.st.lock());
        loop {
            match std::mem::replace(&mut st.state, TicketState::Taken) {
                TicketState::Ready(reply) => return Ok(reply),
                TicketState::Pending => {
                    st.state = TicketState::Pending;
                    let now = Instant::now();
                    if now >= deadline {
                        drop(st);
                        self.inner.cancel.store(true, Relaxed);
                        return Err(WaitTimeout);
                    }
                    st = lock_unpoisoned(self.inner.cv.wait_timeout(st, deadline - now)).0;
                }
                TicketState::Failed(why) => {
                    drop(st);
                    panic!("order ticket failed: {why}");
                }
                TicketState::Taken => {
                    drop(st);
                    panic!("order ticket already consumed");
                }
            }
        }
    }

    /// Non-blocking poll: `Some(reply)` once ready (takes it), `None`
    /// while pending. Panics like [`Self::wait`] on an abandoned ticket
    /// or a double take.
    pub fn try_get(&self) -> Option<OrderReply> {
        match self.take_result() {
            Some(Ok(reply)) => Some(reply),
            Some(Err(why)) => panic!("order ticket failed: {why}"),
            None => None,
        }
    }

    /// Whether the ticket has resolved (reply ready, taken, or failed).
    pub fn is_finished(&self) -> bool {
        !self.inner.is_pending()
    }

    /// The request's flight recorder: inspect the recorded spans,
    /// measure [`RequestTrace::coverage`], or render
    /// [`RequestTrace::to_chrome_json`] once the reply arrived. Clone
    /// the handle out before `wait` consumes the ticket to keep it.
    pub fn trace(&self) -> Arc<RequestTrace> {
        Arc::clone(&self.inner.trace)
    }

    /// Explicitly cancel the request without dropping the ticket. After
    /// cancellation the pipeline may fail the ticket, so `wait`/`try_get`
    /// can panic; poll [`Self::is_finished`] if the race matters.
    pub fn cancel(&self) {
        self.inner.cancel.store(true, Relaxed);
    }
}

impl Drop for Ticket {
    fn drop(&mut self) {
        // Withdraw interest; harmless if the reply was already taken.
        self.inner.cancel.store(true, Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounded_queue_push_pop_fifo() {
        let q = BoundedQueue::new(4);
        assert_eq!(q.push(1).unwrap(), 1);
        assert_eq!(q.push(2).unwrap(), 2);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn bounded_queue_blocks_at_capacity() {
        use std::sync::atomic::AtomicBool;
        let q = BoundedQueue::new(1);
        q.push(0u32).unwrap();
        let pushed = AtomicBool::new(false);
        std::thread::scope(|s| {
            let q = &q;
            let pushed = &pushed;
            s.spawn(move || {
                q.push(1).unwrap(); // blocks until the pop below
                pushed.store(true, Relaxed);
            });
            std::thread::sleep(std::time::Duration::from_millis(30));
            assert!(!pushed.load(Relaxed), "push must block while full");
            assert_eq!(q.pop(), Some(0));
        });
        assert!(pushed.load(Relaxed));
        assert_eq!(q.pop(), Some(1));
    }

    #[test]
    fn closed_queue_rejects_pushes_and_drains_pops() {
        let q = BoundedQueue::new(4);
        q.push(7u8).unwrap();
        q.close();
        assert_eq!(q.push(8), Err(8));
        assert_eq!(q.pop(), Some(7), "accepted items still drain");
        assert_eq!(q.pop(), None, "closed + empty ends the consumer");
    }

    #[test]
    fn ticket_roundtrip_and_drop_cancels() {
        let (ticket, inner) = Ticket::new();
        assert!(!ticket.is_finished());
        assert!(ticket.try_get().is_none());
        inner.fulfill(OrderReply {
            perm: vec![0],
            fill_in: None,
            pre_secs: 0.0,
            order_secs: 0.0,
            total_secs: 0.0,
            rounds: 0,
            gc_count: 0,
            gc_secs: 0.0,
            modeled_time: 0.0,
            round_samples: Vec::new(),
        });
        assert!(ticket.is_finished());
        let reply = ticket.wait();
        assert_eq!(reply.perm, vec![0]);

        let (ticket, inner) = Ticket::new();
        assert!(!inner.is_cancelled());
        drop(ticket);
        assert!(inner.is_cancelled(), "dropping a ticket must cancel it");
    }

    #[test]
    #[should_panic(expected = "order ticket failed")]
    fn failed_ticket_panics_on_wait() {
        let (ticket, inner) = Ticket::new();
        inner.fail("scheduler shut down");
        ticket.wait();
    }

    #[test]
    fn push_all_fits_in_one_reservation() {
        let q = BoundedQueue::new(8);
        assert_eq!(q.push_all(vec![1, 2, 3], Lane::Batch).unwrap(), 3);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
    }

    #[test]
    fn push_all_larger_than_capacity_drains_through() {
        // cap 2, batch 5: the pusher must hand chunks to a concurrent
        // consumer instead of deadlocking.
        let q = BoundedQueue::new(2);
        std::thread::scope(|s| {
            let q = &q;
            s.spawn(move || {
                assert!(q.push_all((0..5u32).collect(), Lane::Batch).is_ok());
            });
            let mut got = Vec::new();
            for _ in 0..5 {
                got.push(q.pop().unwrap());
            }
            assert_eq!(got, vec![0, 1, 2, 3, 4], "batch order preserved");
        });
    }

    #[test]
    fn push_all_returns_remainder_when_closed() {
        let q = BoundedQueue::new(4);
        q.close();
        assert_eq!(q.push_all(vec![7u8, 8], Lane::Batch), Err(vec![7, 8]));
    }

    #[test]
    fn wait_deadline_returns_ready_replies() {
        let (ticket, inner) = Ticket::new();
        inner.fulfill(OrderReply {
            perm: vec![0],
            fill_in: None,
            pre_secs: 0.0,
            order_secs: 0.0,
            total_secs: 0.0,
            rounds: 0,
            gc_count: 0,
            gc_secs: 0.0,
            modeled_time: 0.0,
            round_samples: Vec::new(),
        });
        let reply = ticket
            .wait_deadline(Duration::from_secs(5))
            .expect("ready ticket resolves immediately");
        assert_eq!(reply.perm, vec![0]);
    }

    fn dummy_reply(tag: i32) -> OrderReply {
        OrderReply {
            perm: vec![tag],
            fill_in: None,
            pre_secs: 0.0,
            order_secs: 0.0,
            total_secs: 0.0,
            rounds: 0,
            gc_count: 0,
            gc_secs: 0.0,
            modeled_time: 0.0,
            round_samples: Vec::new(),
        }
    }

    #[test]
    fn wait_batch_delivers_indices_in_completion_order() {
        let (t0, i0) = Ticket::new();
        let (t1, i1) = Ticket::new();
        let (t2, i2) = Ticket::new();
        let batch = WaitBatch::new();
        assert!(t0.attach_watcher(&batch, 0));
        assert!(t1.attach_watcher(&batch, 1));
        assert!(t2.attach_watcher(&batch, 2));
        i2.fulfill(dummy_reply(2));
        i0.fail("cancelled");
        i1.fulfill(dummy_reply(1));
        assert_eq!(batch.wait_one(), 2, "completion order, not submit order");
        assert_eq!(batch.wait_one(), 0);
        assert_eq!(batch.wait_one(), 1);
        assert!(t2.take_result().unwrap().is_ok());
        assert!(t0.take_result().unwrap().is_err());
        assert!(t1.take_result().unwrap().is_ok());
    }

    #[test]
    fn take_result_reports_a_double_take_as_err() {
        // A batch harvest must not lose the rest of the batch to one
        // ticket the caller already consumed via try_get.
        let (ticket, inner) = Ticket::new();
        inner.fulfill(dummy_reply(3));
        assert!(ticket.try_get().is_some());
        assert!(ticket.take_result().unwrap().is_err(), "consumed → Err, no panic");
    }

    #[test]
    fn attach_watcher_rejects_resolved_tickets() {
        let (ticket, inner) = Ticket::new();
        inner.fulfill(dummy_reply(7));
        let batch = WaitBatch::new();
        assert!(
            !ticket.attach_watcher(&batch, 0),
            "already-resolved tickets harvest immediately"
        );
        assert_eq!(ticket.take_result().unwrap().unwrap().perm, vec![7]);
    }

    #[test]
    fn wait_deadline_expiry_cancels_the_request() {
        let (ticket, inner) = Ticket::new();
        let err = ticket
            .wait_deadline(Duration::from_millis(5))
            .expect_err("pending ticket must time out");
        assert_eq!(err, WaitTimeout);
        assert!(inner.is_cancelled(), "expiry must cancel the request");
    }

    #[test]
    fn interactive_lane_overtakes_batch_in_pop_order() {
        let q = BoundedQueue::new(8);
        q.push_lane('b', Lane::Batch).unwrap();
        q.push_lane('c', Lane::Batch).unwrap();
        q.push_lane('i', Lane::Interactive).unwrap();
        q.push_lane('j', Lane::Interactive).unwrap();
        assert_eq!(q.len(), 4, "capacity accounting spans both lanes");
        let order: Vec<char> = (0..4).map(|_| q.pop().unwrap()).collect();
        assert_eq!(order, vec!['i', 'j', 'b', 'c'], "interactive first, FIFO within");
    }

    #[test]
    fn try_push_sheds_instead_of_blocking() {
        let q = BoundedQueue::new(1);
        assert!(q.try_push(1u8, Lane::Batch).is_ok());
        match q.try_push(2, Lane::Interactive) {
            Err(TryPushError::Full(item)) => assert_eq!(item, 2, "item handed back"),
            _ => panic!("full queue must shed, not block"),
        }
        assert_eq!(q.pop(), Some(1));
        q.close();
        match q.try_push(3, Lane::Batch) {
            Err(TryPushError::Closed(item)) => assert_eq!(item, 3),
            _ => panic!("closed queue must report Closed"),
        }
    }

    #[test]
    fn wait_result_returns_typed_errors_without_panicking() {
        let (ticket, inner) = Ticket::new();
        inner.fail("boom");
        assert_eq!(ticket.wait_result(), Err(OrderError::Failed("boom".into())));

        let (ticket, inner) = Ticket::new();
        inner.fail_with(OrderError::Cancelled);
        assert_eq!(ticket.wait_result(), Err(OrderError::Cancelled));

        let (ticket, inner) = Ticket::new();
        inner.fail_with(OrderError::DeadlineExceeded);
        assert_eq!(ticket.wait_result(), Err(OrderError::DeadlineExceeded));

        let (ticket, inner) = Ticket::new();
        inner.fulfill(dummy_reply(4));
        assert_eq!(ticket.wait_result().unwrap().perm, vec![4]);
    }

    #[test]
    fn expire_deadline_sets_cancel_and_routes_the_outcome() {
        let (ticket, inner) = Ticket::new();
        assert!(!inner.deadline_fired());
        inner.expire_deadline();
        assert!(inner.is_cancelled(), "expiry aborts via the existing cancel flag");
        assert!(inner.deadline_fired());
        inner.fail_with(OrderError::DeadlineExceeded);
        assert_eq!(ticket.wait_result(), Err(OrderError::DeadlineExceeded));
    }

    #[test]
    fn order_error_displays_are_stable() {
        assert_eq!(OrderError::Failed("x".into()).to_string(), "x");
        assert_eq!(OrderError::Cancelled.to_string(), "request cancelled");
        assert_eq!(
            OrderError::DeadlineExceeded.to_string(),
            "request deadline exceeded"
        );
        let r = OrderError::Rejected {
            retry_after_hint: Duration::from_millis(25),
        };
        assert!(r.to_string().contains("retry after ~25ms"), "{r}");
    }
}
