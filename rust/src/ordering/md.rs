//! Textbook minimum degree ordering on explicit elimination graphs
//! (Rose 1972) — the slow-but-obviously-correct oracle for testing the
//! quotient-graph implementations, and the didactic §2.1 reference.

use std::collections::BTreeSet;

use crate::graph::csr::SymGraph;
use crate::ordering::{Ordering, OrderingResult};

/// Exact minimum degree with deterministic tie-breaking (lowest index).
/// O(n² log n)-ish: only for small graphs / tests.
#[derive(Clone, Copy, Debug, Default)]
pub struct MinDegree;

impl Ordering for MinDegree {
    fn name(&self) -> &'static str {
        "md"
    }

    fn order(&self, g: &SymGraph) -> OrderingResult {
        let n = g.n;
        let mut adj: Vec<BTreeSet<i32>> = (0..n)
            .map(|v| g.neighbors(v).iter().cloned().collect())
            .collect();
        let mut alive: BTreeSet<i32> = (0..n as i32).collect();
        let mut perm = Vec::with_capacity(n);
        while !alive.is_empty() {
            // Pivot: min degree, ties by index (BTreeSet iteration order).
            let p = *alive
                .iter()
                .min_by_key(|&&v| (adj[v as usize].len(), v))
                .unwrap();
            // Form the clique among p's neighbors.
            let nbrs: Vec<i32> = adj[p as usize].iter().cloned().collect();
            for (i, &a) in nbrs.iter().enumerate() {
                adj[a as usize].remove(&p);
                for &b in &nbrs[i + 1..] {
                    adj[a as usize].insert(b);
                    adj[b as usize].insert(a);
                }
            }
            adj[p as usize].clear();
            alive.remove(&p);
            perm.push(p);
        }
        let mut r = OrderingResult::new(perm);
        r.stats.rounds = n as u64;
        r.stats.pivots = n as u64;
        r
    }
}

/// The exact degree sequence the algorithm saw at each pivot selection —
/// exposed for tests that validate AMD's approximate degrees are upper
/// bounds of the true degrees.
pub fn md_with_degrees(g: &SymGraph) -> (Vec<i32>, Vec<usize>) {
    let n = g.n;
    let mut adj: Vec<BTreeSet<i32>> = (0..n)
        .map(|v| g.neighbors(v).iter().cloned().collect())
        .collect();
    let mut alive: BTreeSet<i32> = (0..n as i32).collect();
    let mut perm = Vec::with_capacity(n);
    let mut degs = Vec::with_capacity(n);
    while !alive.is_empty() {
        let p = *alive
            .iter()
            .min_by_key(|&&v| (adj[v as usize].len(), v))
            .unwrap();
        degs.push(adj[p as usize].len());
        let nbrs: Vec<i32> = adj[p as usize].iter().cloned().collect();
        for (i, &a) in nbrs.iter().enumerate() {
            adj[a as usize].remove(&p);
            for &b in &nbrs[i + 1..] {
                adj[a as usize].insert(b);
                adj[b as usize].insert(a);
            }
        }
        adj[p as usize].clear();
        alive.remove(&p);
        perm.push(p);
    }
    (perm, degs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ordering::test_support::check_ordering_contract;
    use crate::symbolic::fill_in;

    #[test]
    fn orders_path_graph_with_no_fill() {
        let n = 10;
        let edges: Vec<(usize, usize)> = (0..n - 1).map(|i| (i, i + 1)).collect();
        let g = SymGraph::from_edges(n, &edges);
        let r = MinDegree.order(&g);
        check_ordering_contract(&g, &r);
        assert_eq!(fill_in(&g, &r.perm), 0, "MD is optimal on paths");
    }

    #[test]
    fn orders_star_with_no_fill() {
        let g = SymGraph::from_edges(6, &[(0, 5), (1, 5), (2, 5), (3, 5), (4, 5)]);
        let r = MinDegree.order(&g);
        check_ordering_contract(&g, &r);
        assert_eq!(fill_in(&g, &r.perm), 0);
        // Center must be eliminated last.
        assert_eq!(*r.perm.last().unwrap(), 5);
    }

    #[test]
    fn beats_natural_order_on_grid() {
        let g = crate::matgen::mesh2d(8, 8);
        let r = MinDegree.order(&g);
        check_ordering_contract(&g, &r);
        let natural: Vec<i32> = (0..g.n as i32).collect();
        assert!(fill_in(&g, &r.perm) < fill_in(&g, &natural));
    }

    #[test]
    fn degrees_are_nondecreasing_start() {
        let g = crate::matgen::random_graph(40, 4, 1);
        let (perm, degs) = md_with_degrees(&g);
        assert_eq!(perm.len(), g.n);
        assert_eq!(degs.len(), g.n);
        // First pivot has the global minimum degree.
        let dmin = (0..g.n).map(|v| g.degree(v)).min().unwrap();
        assert_eq!(degs[0], dmin);
    }

    #[test]
    fn handles_empty_graph() {
        let g = SymGraph::from_edges(5, &[]);
        let r = MinDegree.order(&g);
        check_ordering_contract(&g, &r);
    }
}
