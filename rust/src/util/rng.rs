//! Deterministic pseudo-random number generation.
//!
//! The offline crate registry has no `rand`, so we carry our own small,
//! well-known generators: SplitMix64 for seeding and xoshiro256** for the
//! main stream. Both are reproducible across platforms, which matters for
//! the paper's evaluation protocol (five fixed random permutations shared by
//! every ordering method — §4.2 / Table 4.2 of the paper).

/// The SplitMix64 step as a stateless mixing function: `splitmix64(x)`
/// is exactly `SplitMix64::new(x).next_u64()`. Doubles as a cheap,
/// high-quality single-word hash (e.g. the reduction layer's commutative
/// adjacency fingerprints).
#[inline]
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// SplitMix64 — used to expand a single `u64` seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let out = splitmix64(self.state);
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        out
    }
}

/// xoshiro256** — fast, high-quality, deterministic PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a seed; any seed (including 0) is fine.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for workload generation; we accept the tiny modulo bias of the
    /// fast path only when `bound` is small relative to 2^64).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Return `true` with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// A uniformly random permutation of `0..n` (as `i32` — the index type
    /// used throughout the ordering code).
    pub fn permutation(&mut self, n: usize) -> Vec<i32> {
        let mut p: Vec<i32> = (0..n as i32).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix64_fn_matches_the_stream_head() {
        for seed in [0u64, 1, 42, u64::MAX, 0xDEAD_BEEF] {
            assert_eq!(splitmix64(seed), SplitMix64::new(seed).next_u64());
        }
        // And the stream itself stays a γ-stride walk of the finalizer.
        let mut sm = SplitMix64::new(7);
        sm.next_u64();
        assert_eq!(sm.next_u64(), splitmix64(7u64.wrapping_add(0x9E37_79B9_7F4A_7C15)));
    }

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for bound in [1usize, 2, 10, 1000] {
            for _ in 0..1000 {
                assert!(r.below(bound) < bound);
            }
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn permutation_is_valid() {
        let mut r = Rng::new(3);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &v in &p {
            assert!(!seen[v as usize]);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn shuffle_changes_order() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..64).collect();
        r.shuffle(&mut xs);
        assert_ne!(xs, (0..64).collect::<Vec<_>>());
    }
}
