//! The Layer-3 coordinator: an ordering/solve *service*.
//!
//! The paper's AMD use case is a pipeline stage inside a sparse direct
//! solver; this module packages the library as one deployable component:
//! a request queue, an ordering executor, and a dedicated **solver
//! thread** that owns the non-`Sync` PJRT engine and serves factor+solve
//! requests over a channel. Metrics (latency summaries, counters) are
//! collected per method.
//!
//! ## Warm ordering path
//!
//! The service owns **one persistent
//! [`OrderingRuntime`](crate::ordering::paramd::runtime::OrderingRuntime)**
//! — a pool of worker threads spawned at construction and parked between
//! requests — plus an
//! [`ArenaPool`](crate::ordering::paramd::arena::ArenaPool) of reusable
//! per-run storage. Every ParAMD request borrows the shared runtime and a
//! pooled arena, so the steady state neither spawns threads nor performs
//! O(n)/O(nnz) allocations inside the ordering (the reply's owned
//! permutation is the only per-request copy). Concurrent requests are
//! safe: the runtime serializes jobs internally and each request checks
//! out its own arena, so [`Service`] is `Sync` and callable through
//! `&self` from many threads.
//!
//! The pool size is fixed at construction ([`Service::new`] /
//! [`Service::with_order_threads`]); a request's `Method::ParAmd.threads`
//! knob is superseded by the shared pool.

pub mod metrics;
pub mod request;

pub use metrics::Metrics;
pub use request::{Method, OrderReply, OrderRequest, SolveReply, SolveSpec};

use std::sync::{mpsc, Mutex};

use crate::cholesky::{self, DenseTail, NativeDense};
use crate::graph::symmetrize_parallel;
use crate::nd::NestedDissection;
use crate::ordering::paramd::arena::ArenaPool;
use crate::ordering::paramd::runtime::OrderingRuntime;
use crate::ordering::{
    amd_seq::AmdSeq, md::MinDegree, mmd::Mmd, paramd::ParAmd, Ordering as _, OrderingResult,
};
use crate::symbolic;
use crate::util::timer::Timer;

/// The ordering service. Construct once, submit requests (from any number
/// of threads), read metrics.
pub struct Service {
    metrics: Mutex<Metrics>,
    /// Threads used for the symmetrization pre-processing (§4.2).
    pre_threads: usize,
    /// Dense-tail policy handed to the solver.
    tail: DenseTail,
    /// Channel to the dedicated PJRT solver thread (None = native only).
    solver: Option<SolverHandle>,
    /// Persistent ParAMD worker pool shared by all ordering requests.
    order_rt: OrderingRuntime,
    /// Pooled arenas: warm storage checked out per ordering request.
    arenas: ArenaPool,
}

struct SolverHandle {
    tx: Mutex<mpsc::Sender<SolveJob>>,
    _thread: std::thread::JoinHandle<()>,
}

struct SolveJob {
    a: crate::graph::csr::CsrMatrix,
    perm: Vec<i32>,
    b: Vec<f64>,
    tail: DenseTail,
    reply: mpsc::Sender<Result<SolveReply, String>>,
}

impl Service {
    /// A service with the native dense engine only. The persistent
    /// ordering pool is sized to `pre_threads` (see
    /// [`Self::with_order_threads`] to size it independently).
    pub fn new(pre_threads: usize) -> Self {
        let pre_threads = pre_threads.max(1);
        Self {
            metrics: Mutex::new(Metrics::default()),
            pre_threads,
            tail: DenseTail::default(),
            solver: None,
            order_rt: OrderingRuntime::new(pre_threads),
            arenas: ArenaPool::new(),
        }
    }

    /// Rebuild the persistent ordering pool with `threads` workers.
    pub fn with_order_threads(mut self, threads: usize) -> Self {
        self.order_rt = OrderingRuntime::new(threads.max(1));
        self
    }

    /// Attach the PJRT-backed solver thread. The engine is created *on*
    /// the thread (its FFI handles are not `Sync`, DESIGN.md §4) from
    /// the given artifacts directory.
    pub fn with_pjrt_solver(mut self, artifacts_dir: std::path::PathBuf) -> Result<Self, String> {
        let (tx, rx) = mpsc::channel::<SolveJob>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<usize, String>>();
        let thread = std::thread::spawn(move || {
            let engine = match crate::runtime::PjrtEngine::load_dir(&artifacts_dir) {
                Ok(e) => {
                    let max = e
                        .sizes(crate::runtime::ArtifactKind::Chol)
                        .last()
                        .copied()
                        .unwrap_or(0);
                    let _ = ready_tx.send(Ok(max));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e.to_string()));
                    return;
                }
            };
            let dense = crate::runtime::PjrtDense { engine: &engine };
            while let Ok(job) = rx.recv() {
                let out = solve_with(&job.a, &job.perm, &job.b, job.tail, &dense, "pjrt");
                let _ = job.reply.send(out);
            }
        });
        let max_tile = ready_rx
            .recv()
            .map_err(|e| e.to_string())?
            .map_err(|e| format!("pjrt solver init: {e}"))?;
        // Clamp the dense-tail policy to what the artifacts can factor.
        if let DenseTail::Auto { max, min_density } = self.tail {
            self.tail = DenseTail::Auto {
                max: max.min(max_tile),
                min_density,
            };
        }
        self.solver = Some(SolverHandle {
            tx: Mutex::new(tx),
            _thread: thread,
        });
        Ok(self)
    }

    pub fn with_tail(mut self, tail: DenseTail) -> Self {
        self.tail = tail;
        self
    }

    /// Snapshot of the per-method metrics.
    pub fn metrics(&self) -> Metrics {
        self.metrics.lock().unwrap().clone()
    }

    /// Number of idle pooled arenas (observability hook).
    pub fn idle_arenas(&self) -> usize {
        self.arenas.idle()
    }

    /// Run an ordering request (synchronously; ParAMD parallelism happens
    /// inside on the shared persistent pool). Includes the `|A| + |A^T|`
    /// pre-processing unless the request says the input is already
    /// symmetric (§4.2's advice).
    pub fn order(&self, req: &OrderRequest) -> OrderReply {
        let total = Timer::new();
        let tpre = Timer::new();
        let g = if let Some(g) = &req.pattern {
            g.clone()
        } else {
            symmetrize_parallel(req.matrix.as_ref().expect("matrix or pattern"), self.pre_threads)
        };
        let pre_secs = tpre.secs();

        // What a reply needs from an ordering: the owned permutation plus
        // three scalar stats. Extracting just these keeps the warm ParAMD
        // arm down to a single O(n) copy (the reply's own `perm`).
        fn parts(r: OrderingResult) -> (Vec<i32>, u64, u64, f64) {
            (
                r.perm,
                r.stats.rounds,
                r.stats.gc_count,
                r.stats.modeled_time,
            )
        }

        let tord = Timer::new();
        let (perm, rounds, gc_count, modeled_time) = match &req.method {
            Method::Amd => parts(AmdSeq::default().order(&g)),
            Method::Mmd => parts(Mmd::default().order(&g)),
            Method::MinDegree => parts(MinDegree.order(&g)),
            Method::Nd => parts(NestedDissection::default().order(&g)),
            Method::ParAmd {
                threads: _,
                mult,
                lim_total,
            } => {
                // Warm path: persistent pool + pooled arena. The request's
                // `threads` knob is superseded by the shared pool size.
                let cfg = ParAmd::new(self.order_rt.threads())
                    .with_mult(*mult)
                    .with_lim_total(*lim_total);
                let mut arena = self.arenas.acquire();
                let r = cfg.order_into(&self.order_rt, &mut arena, &g);
                // The reply must own its permutation; everything else is
                // read off the borrowed pooled result.
                let out = (
                    r.perm.clone(),
                    r.stats.rounds,
                    r.stats.gc_count,
                    r.stats.modeled_time,
                );
                self.arenas.release(arena);
                out
            }
        };
        let order_secs = tord.secs();

        let fill = if req.compute_fill {
            Some(symbolic::fill_in(&g, &perm))
        } else {
            None
        };
        let reply = OrderReply {
            perm,
            fill_in: fill,
            pre_secs,
            order_secs,
            total_secs: total.secs(),
            rounds,
            gc_count,
            modeled_time,
        };
        self.metrics
            .lock()
            .unwrap()
            .record(req.method.name(), reply.total_secs, reply.fill_in);
        reply
    }

    /// Order + factor + solve. Uses the PJRT solver thread when attached,
    /// otherwise the native dense engine inline.
    pub fn solve(&self, req: &OrderRequest, spec: &SolveSpec) -> Result<SolveReply, String> {
        let a = req
            .matrix
            .as_ref()
            .ok_or("solve requires an explicit matrix")?
            .clone();
        let ordered = self.order(req);
        let b = match spec {
            SolveSpec::OnesSolution => {
                let ones = vec![1.0; a.nrows];
                let mut b = vec![0.0; a.nrows];
                a.matvec(&ones, &mut b);
                b
            }
            other => other.rhs(a.nrows),
        };
        let t = Timer::new();
        let mut out = if let Some(handle) = &self.solver {
            let (reply_tx, reply_rx) = mpsc::channel();
            handle
                .tx
                .lock()
                .unwrap()
                .send(SolveJob {
                    a,
                    perm: ordered.perm.clone(),
                    b,
                    tail: self.tail,
                    reply: reply_tx,
                })
                .map_err(|e| e.to_string())?;
            reply_rx.recv().map_err(|e| e.to_string())??
        } else {
            solve_with(&a, &ordered.perm, &b, self.tail, &NativeDense, "native")?
        };
        out.order_secs = ordered.order_secs;
        out.pre_secs = ordered.pre_secs;
        out.total_secs = ordered.total_secs + t.secs();
        Ok(out)
    }
}

/// Shared solve path (used inline and on the solver thread).
fn solve_with(
    a: &crate::graph::csr::CsrMatrix,
    perm: &[i32],
    b: &[f64],
    tail: DenseTail,
    dense: &dyn crate::cholesky::DenseCholesky,
    engine: &'static str,
) -> Result<SolveReply, String> {
    let tfac = Timer::new();
    let f = cholesky::factor(a, perm, tail, dense)?;
    let factor_secs = tfac.secs();
    let tsol = Timer::new();
    let x = cholesky::solve(&f, b);
    let solve_secs = tsol.secs();
    let resid = cholesky::residual(a, &x, b);
    Ok(SolveReply {
        x,
        residual: resid,
        nnz_l: f.nnz_l,
        dense_tail_cols: f.perm.len() - f.split,
        factor_secs,
        solve_secs,
        engine,
        order_secs: 0.0,
        pre_secs: 0.0,
        total_secs: 0.0,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::{mesh2d, spd_from_graph};

    fn spd_request(method: Method) -> OrderRequest {
        OrderRequest {
            matrix: Some(spd_from_graph(&mesh2d(12, 12), 1.0)),
            pattern: None,
            method,
            compute_fill: true,
        }
    }

    #[test]
    fn order_via_every_method() {
        let svc = Service::new(2);
        for m in [
            Method::Amd,
            Method::Mmd,
            Method::Nd,
            Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 8192,
            },
        ] {
            let rep = svc.order(&spd_request(m));
            assert_eq!(rep.perm.len(), 144);
            assert!(rep.fill_in.unwrap() >= 0);
        }
        assert_eq!(svc.metrics().total_requests(), 4);
    }

    #[test]
    fn repeated_paramd_requests_reuse_the_arena() {
        let svc = Service::new(2);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(mesh2d(14, 14)),
            method: Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        };
        for _ in 0..3 {
            let rep = svc.order(&req);
            assert_eq!(rep.perm.len(), 196);
        }
        assert_eq!(svc.idle_arenas(), 1, "sequential requests share one arena");
    }

    #[test]
    fn concurrent_paramd_requests_pass_contract() {
        use crate::ordering::test_support::check_ordering_contract;
        let svc = Service::new(2);
        std::thread::scope(|s| {
            for i in 0..4usize {
                let svc = &svc;
                s.spawn(move || {
                    let g = mesh2d(8 + i, 9);
                    let rep = svc.order(&OrderRequest {
                        matrix: None,
                        pattern: Some(g.clone()),
                        method: Method::ParAmd {
                            threads: 2,
                            mult: 1.1,
                            lim_total: 0,
                        },
                        compute_fill: false,
                    });
                    let r = crate::ordering::OrderingResult::new(rep.perm);
                    check_ordering_contract(&g, &r);
                });
            }
        });
        assert_eq!(svc.metrics().total_requests(), 4);
    }

    #[test]
    fn solve_native_end_to_end() {
        let svc = Service::new(1);
        let req = spd_request(Method::Amd);
        let rep = svc
            .solve(&req, &SolveSpec::OnesSolution)
            .expect("solve must succeed");
        assert!(rep.residual < 1e-10, "residual {:e}", rep.residual);
        // b was built from x = ones.
        for xi in &rep.x {
            assert!((xi - 1.0).abs() < 1e-8);
        }
        assert_eq!(rep.engine, "native");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn solve_pjrt_end_to_end() {
        let svc = Service::new(1).with_pjrt_solver("artifacts".into());
        let svc = match svc {
            Ok(s) => s,
            Err(e) => panic!("pjrt solver init failed: {e} (run `make artifacts`)"),
        };
        let a = crate::matgen::laplacian_matrix(10, 10);
        let req = OrderRequest {
            matrix: Some(a),
            pattern: None,
            method: Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 8192,
            },
            compute_fill: false,
        };
        let rep = svc.solve(&req, &SolveSpec::RandomRhs { seed: 3 }).unwrap();
        assert!(rep.residual < 1e-10, "residual {:e}", rep.residual);
        assert_eq!(rep.engine, "pjrt");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_solver_reports_disabled_feature() {
        let err = Service::new(1)
            .with_pjrt_solver("artifacts".into())
            .err()
            .expect("stub must refuse");
        assert!(err.contains("pjrt"), "unexpected error: {err}");
    }

    #[test]
    fn pattern_requests_skip_preprocessing() {
        let svc = Service::new(1);
        let req = OrderRequest {
            matrix: None,
            pattern: Some(mesh2d(10, 10)),
            method: Method::Amd,
            compute_fill: false,
        };
        let rep = svc.order(&req);
        assert_eq!(rep.perm.len(), 100);
    }
}
