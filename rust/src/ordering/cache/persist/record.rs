//! The **on-disk record format** of the persistent result-cache tier.
//!
//! Both persist files (`log.bin`, `snapshot.bin`) share one layout, all
//! fields little-endian:
//!
//! ```text
//! file   := file_header frame*
//! file_header := FILE_MAGIC:u32  FORMAT_VERSION:u32
//! frame  := RECORD_MAGIC:u32  payload_len:u32  checksum:u64  payload
//! ```
//!
//! Every frame is **independently checksummed and length-prefixed**, so
//! a torn tail write (process killed mid-append) is detectable by
//! construction: the partial frame fails its length or checksum check
//! and the reader truncates there instead of replaying garbage. The
//! checksum is a [`splitmix64`] chain over the payload's 8-byte chunks,
//! seeded with the payload length — dependency-free and strong enough
//! to catch torn writes and bit rot (full collisions additionally have
//! to survive the in-memory tier's exact CSR verify on first hit).
//!
//! The payload carries the complete cache identity and value:
//! fingerprint + config/weights salt ([`CacheKey`]), the **store
//! version tag** (callers that reuse graph ids with changed structure
//! bump it to invalidate every older record at recovery), a creation
//! timestamp for TTL expiry, the exact-verify CSR + weights, and the
//! [`CachedOrdering`] replayed on a hit.
//!
//! Decoding never panics: every read is bounds-checked and every
//! failure is a reason string the caller wraps into a typed
//! [`PersistError`](super::PersistError) or a counted recovery reject.

use crate::graph::csr::SymGraph;
use crate::graph::fingerprint::Fingerprint;
use crate::ordering::cache::{CacheKey, CachedOrdering};
use crate::util::rng::splitmix64;

/// First 4 bytes of every persist file ("PMC1").
pub const FILE_MAGIC: u32 = 0x504D_4331;
/// On-disk format revision; bumping it orphans (quarantines) old files.
pub const FORMAT_VERSION: u32 = 1;
/// First 4 bytes of every record frame ("PCRE").
pub const RECORD_MAGIC: u32 = 0x5043_5245;
/// Bytes of the per-file header (`FILE_MAGIC` + `FORMAT_VERSION`).
pub const FILE_HEADER_BYTES: usize = 8;
/// Bytes of the per-frame header (magic + length + checksum).
pub const FRAME_HEADER_BYTES: usize = 16;
/// Upper bound on a single payload; larger length prefixes are treated
/// as corruption rather than allocated.
pub const MAX_RECORD_BYTES: usize = 1 << 30;

/// A fully decoded persisted cache entry.
#[derive(Clone, Debug)]
pub struct Record {
    /// The cache identity: fingerprint + config/weights salt.
    pub key: CacheKey,
    /// Store version tag the record was written under.
    pub version: u64,
    /// Creation time, seconds since the Unix epoch (TTL expiry).
    pub created_at: u64,
    /// Exact-verify copy of the keyed graph.
    pub graph: SymGraph,
    /// Exact-verify copy of the seed supervariable weights.
    pub weights: Option<Vec<i32>>,
    /// The ordering replayed on a hit.
    pub value: CachedOrdering,
}

/// The file header every persist file starts with.
pub fn file_header() -> [u8; FILE_HEADER_BYTES] {
    let mut h = [0u8; FILE_HEADER_BYTES];
    h[..4].copy_from_slice(&FILE_MAGIC.to_le_bytes());
    h[4..].copy_from_slice(&FORMAT_VERSION.to_le_bytes());
    h
}

/// Whether `buf` starts with a current-format file header.
pub fn check_file_header(buf: &[u8]) -> bool {
    buf.len() >= FILE_HEADER_BYTES && buf[..FILE_HEADER_BYTES] == file_header()
}

/// Frame checksum: a [`splitmix64`] chain over 8-byte little-endian
/// chunks (zero-padded tail), seeded with the payload length.
pub fn checksum(payload: &[u8]) -> u64 {
    let mut h = splitmix64(0x5045_5253 ^ payload.len() as u64);
    let mut chunks = payload.chunks_exact(8);
    for c in &mut chunks {
        h = splitmix64(h ^ u64::from_le_bytes(c.try_into().expect("8-byte chunk")));
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut last = [0u8; 8];
        last[..rem.len()].copy_from_slice(rem);
        h = splitmix64(h ^ u64::from_le_bytes(last));
    }
    h
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

/// Wrap `payload` in a checksummed, length-prefixed frame.
pub fn frame(payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(FRAME_HEADER_BYTES + payload.len());
    put_u32(&mut out, RECORD_MAGIC);
    put_u32(&mut out, payload.len() as u32);
    put_u64(&mut out, checksum(payload));
    out.extend_from_slice(payload);
    out
}

/// Encode one cache entry as a complete frame (header + payload),
/// borrowing everything — the hot insert path encodes before the entry
/// is moved into the in-memory tier.
pub fn encode(
    key: &CacheKey,
    version: u64,
    created_at: u64,
    graph: &SymGraph,
    weights: Option<&[i32]>,
    value: &CachedOrdering,
) -> Vec<u8> {
    let mut p = Vec::with_capacity(
        160 + graph.rowptr.len() * 8
            + graph.colind.len() * 4
            + weights.map_or(0, |w| w.len() * 4)
            + value.perm.len() * 4
            + value.set_sizes.len() * 4,
    );
    put_u64(&mut p, key.fp.hi);
    put_u64(&mut p, key.fp.lo);
    put_u64(&mut p, key.salt);
    put_u64(&mut p, version);
    put_u64(&mut p, created_at);
    put_u64(&mut p, graph.n as u64);
    put_u64(&mut p, graph.rowptr.len() as u64);
    for &r in &graph.rowptr {
        put_u64(&mut p, r as u64);
    }
    put_u64(&mut p, graph.colind.len() as u64);
    for &c in &graph.colind {
        p.extend_from_slice(&c.to_le_bytes());
    }
    match weights {
        None => put_u64(&mut p, 0),
        Some(ws) => {
            put_u64(&mut p, 1);
            put_u64(&mut p, ws.len() as u64);
            for &w in ws {
                p.extend_from_slice(&w.to_le_bytes());
            }
        }
    }
    put_u64(&mut p, value.perm.len() as u64);
    for &v in &value.perm {
        p.extend_from_slice(&v.to_le_bytes());
    }
    put_u64(&mut p, value.rounds);
    put_u64(&mut p, value.gc_count);
    put_f64(&mut p, value.gc_secs);
    put_f64(&mut p, value.modeled_time);
    put_u64(&mut p, value.set_sizes.len() as u64);
    for &s in &value.set_sizes {
        p.extend_from_slice(&s.to_le_bytes());
    }
    put_u64(&mut p, value.reduced as u64);
    frame(&p)
}

/// Outcome of reading one frame at `off`.
pub enum FrameRead<'a> {
    /// Clean end of file.
    Eof,
    /// The bytes at `off` are not a complete, checksum-valid frame — a
    /// torn tail write or corruption. Nothing at or past `off` can be
    /// trusted (frame lengths chain the walk), so the reader truncates
    /// here.
    Torn(String),
    /// A complete, checksum-valid payload; the next frame starts at
    /// `next`.
    Frame { payload: &'a [u8], next: usize },
}

/// Read the frame starting at byte `off` of `buf` (which excludes the
/// file header — pass `FILE_HEADER_BYTES` for the first frame).
pub fn read_frame(buf: &[u8], off: usize) -> FrameRead<'_> {
    if off >= buf.len() {
        return FrameRead::Eof;
    }
    let rest = &buf[off..];
    if rest.len() < FRAME_HEADER_BYTES {
        return FrameRead::Torn(format!(
            "truncated frame header at offset {off}: {} of {FRAME_HEADER_BYTES} bytes",
            rest.len()
        ));
    }
    let magic = u32::from_le_bytes(rest[..4].try_into().expect("4 bytes"));
    if magic != RECORD_MAGIC {
        return FrameRead::Torn(format!("bad record magic {magic:#x} at offset {off}"));
    }
    let len = u32::from_le_bytes(rest[4..8].try_into().expect("4 bytes")) as usize;
    if len > MAX_RECORD_BYTES {
        return FrameRead::Torn(format!("implausible record length {len} at offset {off}"));
    }
    let sum = u64::from_le_bytes(rest[8..16].try_into().expect("8 bytes"));
    if rest.len() - FRAME_HEADER_BYTES < len {
        return FrameRead::Torn(format!(
            "truncated payload at offset {off}: {} of {len} bytes",
            rest.len() - FRAME_HEADER_BYTES
        ));
    }
    let payload = &rest[FRAME_HEADER_BYTES..FRAME_HEADER_BYTES + len];
    if checksum(payload) != sum {
        return FrameRead::Torn(format!("checksum mismatch at offset {off}"));
    }
    FrameRead::Frame {
        payload,
        next: off + FRAME_HEADER_BYTES + len,
    }
}

/// A bounds-checked little-endian reader; every failure is a reason
/// string, never a panic.
struct Cursor<'a> {
    buf: &'a [u8],
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        if self.buf.len() < n {
            return Err(format!(
                "payload truncated: wanted {n} bytes, {} left",
                self.buf.len()
            ));
        }
        let (head, rest) = self.buf.split_at(n);
        self.buf = rest;
        Ok(head)
    }

    fn u64(&mut self) -> Result<u64, String> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8 bytes")))
    }

    fn f64(&mut self) -> Result<f64, String> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// A length prefix for `elem`-byte elements, validated against the
    /// bytes actually remaining so corruption can't trigger a huge
    /// allocation.
    fn len(&mut self, elem: usize, what: &str) -> Result<usize, String> {
        let n = self.u64()? as usize;
        match n.checked_mul(elem) {
            Some(b) if b <= self.buf.len() => Ok(n),
            _ => Err(format!("{what} length {n} exceeds remaining payload")),
        }
    }

    fn vec_u64_as_usize(&mut self, what: &str) -> Result<Vec<usize>, String> {
        let n = self.len(8, what)?;
        let raw = self.take(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().expect("8 bytes")) as usize)
            .collect())
    }

    fn vec_i32(&mut self, what: &str) -> Result<Vec<i32>, String> {
        let n = self.len(4, what)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect())
    }

    fn vec_u32(&mut self, what: &str) -> Result<Vec<u32>, String> {
        let n = self.len(4, what)?;
        let raw = self.take(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().expect("4 bytes")))
            .collect())
    }
}

/// Decode a checksum-valid payload back into a [`Record`]. Structural
/// inconsistencies (a `rowptr` that doesn't match `n`, truncated
/// vectors) are reported as reasons — a record that checksums but does
/// not decode is quarantined by the caller, never replayed.
pub fn decode_payload(payload: &[u8]) -> Result<Record, String> {
    let mut c = Cursor { buf: payload };
    let hi = c.u64()?;
    let lo = c.u64()?;
    let salt = c.u64()?;
    let version = c.u64()?;
    let created_at = c.u64()?;
    let n = c.u64()? as usize;
    let rowptr = c.vec_u64_as_usize("rowptr")?;
    if n.checked_add(1) != Some(rowptr.len()) {
        return Err(format!("rowptr length {} does not match n={n}", rowptr.len()));
    }
    let colind = c.vec_i32("colind")?;
    if *rowptr.last().expect("rowptr is non-empty") != colind.len() {
        return Err(format!(
            "rowptr end {} does not match colind length {}",
            rowptr.last().expect("rowptr is non-empty"),
            colind.len()
        ));
    }
    let weights = match c.u64()? {
        0 => None,
        1 => Some(c.vec_i32("weights")?),
        w => return Err(format!("bad weights flag {w}")),
    };
    let perm = c.vec_i32("perm")?;
    let rounds = c.u64()?;
    let gc_count = c.u64()?;
    let gc_secs = c.f64()?;
    let modeled_time = c.f64()?;
    let set_sizes = c.vec_u32("set_sizes")?;
    let reduced = c.u64()? as usize;
    if !c.buf.is_empty() {
        return Err(format!("{} trailing payload bytes", c.buf.len()));
    }
    Ok(Record {
        key: CacheKey {
            fp: Fingerprint { hi, lo },
            salt,
        },
        version,
        created_at,
        graph: SymGraph { n, rowptr, colind },
        weights,
        value: CachedOrdering {
            perm,
            rounds,
            gc_count,
            gc_secs,
            modeled_time,
            set_sizes,
            reduced,
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::mesh2d;

    fn sample(weights: bool) -> (CacheKey, SymGraph, Option<Vec<i32>>, CachedOrdering) {
        let g = mesh2d(6, 7);
        let w = weights.then(|| vec![2i32; g.n]);
        let key = CacheKey::new(&g, w.as_deref(), 99);
        let value = CachedOrdering {
            perm: (0..g.n as i32).rev().collect(),
            rounds: 5,
            gc_count: 2,
            gc_secs: 0.25,
            modeled_time: 1.5,
            set_sizes: vec![3, 4, 5],
            reduced: 11,
        };
        (key, g, w, value)
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        for weighted in [false, true] {
            let (key, g, w, value) = sample(weighted);
            let f = encode(&key, 7, 1234, &g, w.as_deref(), &value);
            let FrameRead::Frame { payload, next } = read_frame(&f, 0) else {
                panic!("frame must read back");
            };
            assert_eq!(next, f.len());
            let rec = decode_payload(payload).expect("payload must decode");
            assert_eq!(rec.key, key);
            assert_eq!(rec.version, 7);
            assert_eq!(rec.created_at, 1234);
            assert_eq!(rec.graph, g);
            assert_eq!(rec.weights, w);
            assert_eq!(rec.value.perm, value.perm);
            assert_eq!(rec.value.rounds, value.rounds);
            assert_eq!(rec.value.set_sizes, value.set_sizes);
            assert_eq!(rec.value.reduced, value.reduced);
            assert!((rec.value.modeled_time - value.modeled_time).abs() < 1e-12);
        }
    }

    #[test]
    fn any_single_bit_flip_is_detected() {
        let (key, g, w, value) = sample(true);
        let f = encode(&key, 0, 0, &g, w.as_deref(), &value);
        // Flip one bit in a spread of positions across header + payload.
        for pos in [0, 5, 9, FRAME_HEADER_BYTES, FRAME_HEADER_BYTES + 33, f.len() - 1] {
            let mut bad = f.clone();
            bad[pos] ^= 0x10;
            match read_frame(&bad, 0) {
                FrameRead::Torn(_) => {}
                FrameRead::Eof => panic!("flip at {pos} read as EOF"),
                FrameRead::Frame { .. } => panic!("flip at {pos} went undetected"),
            }
        }
    }

    #[test]
    fn torn_tail_is_detected_at_every_truncation_point() {
        let (key, g, w, value) = sample(false);
        let f = encode(&key, 0, 0, &g, w.as_deref(), &value);
        for cut in [1, FRAME_HEADER_BYTES - 1, FRAME_HEADER_BYTES, f.len() - 1] {
            match read_frame(&f[..cut], 0) {
                FrameRead::Torn(_) => {}
                _ => panic!("cut at {cut} bytes not reported torn"),
            }
        }
        assert!(matches!(read_frame(&f, f.len()), FrameRead::Eof));
    }

    #[test]
    fn checksummed_but_malformed_payload_is_a_typed_reject() {
        // A frame whose payload checksums correctly but is semantic
        // garbage must decode to an error, never panic.
        let garbage = vec![0xABu8; 40];
        let f = frame(&garbage);
        let FrameRead::Frame { payload, .. } = read_frame(&f, 0) else {
            panic!("well-framed garbage must pass the frame check");
        };
        assert!(decode_payload(payload).is_err());
    }

    #[test]
    fn file_header_roundtrips_and_rejects_other_versions() {
        let h = file_header();
        assert!(check_file_header(&h));
        assert!(!check_file_header(&h[..4]));
        let mut old = h;
        old[4] = 0xFF; // other format version
        assert!(!check_file_header(&old));
    }
}
