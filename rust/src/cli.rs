//! Minimal command-line flag parser (clap is unavailable offline).
//!
//! Supports `--flag value`, `--flag=value`, bare `--switch`, and
//! positional arguments.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `argv`. Flags in `switches` never consume a value; all other
    /// `--flag` forms take the next token (or `--flag=value`).
    pub fn parse(argv: impl IntoIterator<Item = String>, switches: &[&str]) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if switches.contains(&stripped) {
                    out.switches.push(stripped.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    out.flags.insert(stripped.to_string(), v);
                } else {
                    out.switches.push(stripped.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env(switches: &[&str]) -> Self {
        Self::parse(std::env::args().skip(1), switches)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_parse<T: std::str::FromStr>(&self, key: &str, default: T) -> T {
        self.get(key)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|x| x.to_string()), &["verbose", "fast"])
    }

    #[test]
    fn flags_and_positional() {
        let a = parse(&["order", "--threads", "8", "--mult=1.2", "--verbose", "x.mtx"]);
        assert_eq!(a.positional, vec!["order", "x.mtx"]);
        assert_eq!(a.get("threads"), Some("8"));
        assert_eq!(a.get_parse("threads", 1usize), 8);
        assert_eq!(a.get_parse("mult", 1.0f64), 1.2);
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.get_or("missing", "d"), "d");
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["--fast"]);
        assert!(a.has("fast"));
    }

    #[test]
    fn bad_parse_falls_back() {
        let a = parse(&["--threads", "abc"]);
        assert_eq!(a.get_parse("threads", 7usize), 7);
    }
}
