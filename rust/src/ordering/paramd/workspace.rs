//! Per-thread workspaces and work counters.
//!
//! Each thread carries its own `w`/`wflg` timestamp array for the
//! Algorithm 2.1 degree scan — the paper's O(nt) memory term — plus
//! scratch buffers, an RNG stream for Luby priorities, and the per-round
//! per-phase work counters that feed the critical-path cost model
//! (DESIGN.md §7).

use crate::util::rng::Rng;

/// Work counters for one thread in one round.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundWork {
    /// Words touched during candidate collection + Luby selection.
    pub select: u64,
    /// Words touched during pivot elimination (core AMD).
    pub elim: u64,
    /// Pivots this thread eliminated this round.
    pub pivots: u32,
}

/// Per-thread mutable state.
pub struct Workspace {
    pub tid: usize,
    /// Timestamp array shared between "v ∈ L_me" marking and element
    /// weights (disjoint id spaces), like the sequential engine.
    pub w: Vec<u64>,
    pub wflg: u64,
    n: usize,
    /// Scratch for building L_me.
    pub lme: Vec<i32>,
    /// Scratch for candidate collection.
    pub candidates: Vec<i32>,
    /// Scratch for the pivots this thread won this round.
    pub my_pivots: Vec<i32>,
    /// Scratch for neighborhood enumeration.
    pub nbrs: Vec<i32>,
    /// Per-round cache of candidate neighborhoods (flat CSR layout),
    /// filled by the Luby reset phase and reused by min/validate.
    pub nbr_buf: Vec<i32>,
    pub nbr_ptr: Vec<usize>,
    /// Luby priority RNG.
    pub rng: Rng,
    /// Per-round work log (indexed by round).
    pub work_log: Vec<RoundWork>,
    /// Scratch for supervariable hashing: (hash, var).
    pub hash_scratch: Vec<(u64, i32)>,
}

impl Workspace {
    pub fn new(tid: usize, n: usize, seed: u64) -> Self {
        Self {
            tid,
            w: vec![0u64; n],
            wflg: 1,
            n,
            lme: Vec::new(),
            candidates: Vec::new(),
            my_pivots: Vec::new(),
            nbrs: Vec::new(),
            nbr_buf: Vec::new(),
            nbr_ptr: Vec::new(),
            rng: Rng::new(seed ^ (tid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            work_log: Vec::new(),
            hash_scratch: Vec::new(),
        }
    }

    /// Start a fresh mark epoch, advanced past any stored weight
    /// (`mark + degree ≤ mark + n`) to avoid epoch collisions.
    #[inline]
    pub fn bump_epoch(&mut self) -> u64 {
        self.wflg += self.n as u64 + 2;
        self.wflg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epochs_never_collide_with_stored_weights() {
        let mut ws = Workspace::new(0, 100, 7);
        let m1 = ws.bump_epoch();
        // Largest value stored under epoch m1 is m1 + n.
        let stored = m1 + 100;
        let m2 = ws.bump_epoch();
        assert!(m2 > stored);
    }

    #[test]
    fn rng_streams_differ_by_tid() {
        let mut a = Workspace::new(0, 8, 42);
        let mut b = Workspace::new(1, 8, 42);
        assert_ne!(a.rng.next_u64(), b.rng.next_u64());
    }
}
