//! Pooled per-run storage for warm-path ParAMD.
//!
//! Every `ParAmd::order()` used to allocate ~10 separate O(n)/O(nnz)
//! arrays (the `SharedGraph` slab, per-thread `Workspace`/`ThreadLists`
//! buffers, the `lmin` priority array) and throw them away at the end —
//! on a service handling repeated requests, setup dominated the
//! elimination rounds the paper optimizes. A [`ParAmdArena`] owns all of
//! that state across runs:
//!
//! - storage grows **monotonically** and is reused whenever the next
//!   graph fits (a retained slab larger than needed is just extra elbow);
//! - per-run resets are bulk stores or epoch bumps, never reallocation
//!   (`Workspace::reset` never even rewrites its O(n) timestamp array);
//! - the per-thread hot counters (`lamds`, `sizes`) are padded to cache
//!   lines ([`CachePadded`]) to kill the false sharing the paper flags as
//!   the intra-step bottleneck (§4);
//! - the final log merge, permutation rebuild, and result/detail assembly
//!   all run in pooled scratch, so a warm `order_into` performs no O(n)-
//!   or O(nnz)-sized heap allocations (tracked by [`grow_events`]).
//!
//! [`ArenaPool`] is the multi-request flavor: the coordinator checks an
//! arena out per request and returns it afterwards, so concurrent
//! requests never contend on a single arena while still reusing storage.
//! The pool is **bounded**: at most [`ArenaPool::capacity`] arenas exist
//! (idle + checked out). When every arena is checked out, `acquire`
//! blocks until a release — the memory bound surfaces as backpressure
//! to the caller (the coordinator's scheduler, which in turn stalls its
//! bounded request queue) instead of unbounded allocation. When the cap
//! shrinks below the live set, idle arenas are evicted
//! **LRU-by-slab-size**: the smallest slab goes first (a big warm slab
//! is the most expensive thing to rebuild), stalest first among equals.
//!
//! [`grow_events`]: ParAmdArena::grow_events

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering::Relaxed};
use std::sync::{Condvar, Mutex};

use crate::graph::csr::SymGraph;
use crate::graph::perm::invert_perm_into;
use crate::ordering::{
    rebuild_perm_into, OrderingResult, OrderingStats, RebuildScratch, RoundSample,
};
use crate::util::failpoint;
use crate::util::timer::PhaseTimes;

use super::cost;
use super::lists::{Affinity, ThreadLists};
use super::shared::SharedGraph;
use super::workspace::{RoundWork, Workspace};
use super::{ParAmd, ParAmdDetail};

/// Pads `T` to its own cache line (128 bytes covers adjacent-line
/// prefetching) so per-thread hot counters never false-share.
#[repr(align(128))]
#[derive(Debug, Default)]
pub struct CachePadded<T>(pub T);

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.0
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.0
    }
}

/// One worker thread's private state, pooled across runs.
pub struct ThreadSlot {
    pub lists: ThreadLists,
    pub ws: Workspace,
    /// `(round, pivot)` in local elimination order.
    pub elim_log: Vec<(u32, i32)>,
    pub select_secs: f64,
    pub elim_secs: f64,
}

impl ThreadSlot {
    fn new(tid: usize) -> Self {
        Self {
            lists: ThreadLists::new(tid, 0),
            ws: Workspace::new(tid, 0, 0),
            elim_log: Vec::new(),
            select_secs: 0.0,
            elim_secs: 0.0,
        }
    }

    fn reset(&mut self, n: usize, dmax: usize, log_hint: usize, seed: u64) -> u32 {
        let mut grew = self.lists.reset(n, dmax) + self.ws.reset(n, seed);
        self.ws.set_epoch_stride(dmax);
        self.elim_log.clear();
        // Pre-size the log to the expected per-thread share (aggregate
        // across threads is at most n pivots, so reserving n per slot
        // would pin O(n·t)). A run whose pivot balance overshoots the
        // hint just lets the Vec double once — the capacity is retained,
        // so steady state still doesn't reallocate.
        if self.elim_log.capacity() < log_hint {
            self.elim_log.reserve_exact(log_hint);
            grew += 1;
        }
        self.select_secs = 0.0;
        self.elim_secs = 0.0;
        grew
    }
}

/// Pooled state for the mid-elimination re-reduction sweep
/// ([`crate::ordering::reduce::live`]): the leader-armed trigger flag,
/// the shared fingerprint scratch every worker writes its chunk of, the
/// leader's nomination/postponement buffers, and the cumulative sweep
/// counters that [`ParAmdArena::assemble`] folds into the run's stats.
pub struct RereduceState {
    /// Armed by the leader in phase D; phase E runs the sweep when set.
    pub(crate) flag: AtomicBool,
    /// Per-vertex commutative live-adjacency fingerprints.
    pub(crate) fp: Vec<AtomicU64>,
    /// Per-vertex live-adjacency lengths (bucket discriminator).
    pub(crate) cnt: Vec<AtomicU32>,
    /// Leader scratch: `(hash, live_len, v)` nomination keys.
    pub(crate) keys: Mutex<Vec<(u64, u32, u32)>>,
    /// Dense rows re-postponed mid-run, in postponement order — appended
    /// to the elimination order's tail at assembly.
    pub(crate) postponed: Mutex<Vec<i32>>,
    pub(crate) passes: AtomicUsize,
    pub(crate) twins: AtomicUsize,
    pub(crate) dense: AtomicUsize,
    pub(crate) absorbed: AtomicUsize,
    /// Leader-side sweep nanoseconds (inside the stop-the-world window).
    pub(crate) nanos: AtomicU64,
}

impl RereduceState {
    fn new() -> Self {
        Self {
            flag: AtomicBool::new(false),
            fp: Vec::new(),
            cnt: Vec::new(),
            keys: Mutex::new(Vec::new()),
            postponed: Mutex::new(Vec::new()),
            passes: AtomicUsize::new(0),
            twins: AtomicUsize::new(0),
            dense: AtomicUsize::new(0),
            absorbed: AtomicUsize::new(0),
            nanos: AtomicU64::new(0),
        }
    }

    /// Per-run reset, growing the fingerprint scratch to `n` vertices.
    /// Returns 1 if anything grew (the arena's grow-event accounting).
    fn reset(&mut self, n: usize) -> u32 {
        let mut grew = 0;
        if self.fp.len() < n {
            self.fp.resize_with(n, || AtomicU64::new(0));
            self.cnt.resize_with(n, || AtomicU32::new(0));
            grew = 1;
        }
        self.flag.store(false, Relaxed);
        self.keys.get_mut().unwrap().clear();
        self.postponed.get_mut().unwrap().clear();
        self.passes.store(0, Relaxed);
        self.twins.store(0, Relaxed);
        self.dense.store(0, Relaxed);
        self.absorbed.store(0, Relaxed);
        self.nanos.store(0, Relaxed);
        grew
    }
}

/// Capacity of the per-run [`RoundLog`] ring: at most this many
/// [`RoundSample`]s are retained per job (oldest overwritten first). Far
/// above realistic outer-round counts — multiple elimination retires
/// thousands of pivots per round — so drops are a pathology signal, not
/// a steady-state behavior.
pub const ROUND_RING_CAP: usize = 256;

/// Fixed-footprint ring of per-round telemetry samples, written by the
/// phase-D leader and folded into [`OrderingStats::round_samples`] at
/// assembly. Pooled like everything else in the arena: the ring storage
/// is preallocated to [`ROUND_RING_CAP`] once and reset per run, so
/// recording a round is a mutex lock plus a slot write — no allocation,
/// no unbounded growth on long jobs.
///
/// The writer hands in *cumulative* counters (`nel`, claim failures,
/// GC/sweep nanos); the ring differentiates them against its previous
/// cursors so every sample carries per-round **deltas**. Cumulative
/// pivot/weight totals over everything ever recorded (dropped samples
/// included) are kept so [`Self::fold_into`] can close the books with an
/// exact tail sample.
pub(crate) struct RoundLog {
    inner: Mutex<RoundLogInner>,
}

struct RoundLogInner {
    /// Ring storage (≤ [`ROUND_RING_CAP`] entries, preallocated).
    samples: Vec<RoundSample>,
    /// Next overwrite slot once the ring is full.
    head: usize,
    dropped: u64,
    /// Pivots/weight over *all* recorded samples (dropped included).
    recorded_pivots: u64,
    recorded_weight: u64,
    /// Previous cumulative cursors for delta computation.
    prev_nel: usize,
    prev_claims: usize,
    prev_gc_nanos: u64,
    prev_rr_nanos: u64,
}

impl RoundLog {
    fn new() -> Self {
        Self {
            inner: Mutex::new(RoundLogInner {
                samples: Vec::new(),
                head: 0,
                dropped: 0,
                recorded_pivots: 0,
                recorded_weight: 0,
                prev_nel: 0,
                prev_claims: 0,
                prev_gc_nanos: 0,
                prev_rr_nanos: 0,
            }),
        }
    }

    /// Per-run reset; preallocates the ring storage on first use.
    /// Returns 1 if anything grew (the arena's grow-event accounting).
    fn reset(&mut self) -> u32 {
        let i = self.inner.get_mut().unwrap();
        let mut grew = 0;
        if i.samples.capacity() < ROUND_RING_CAP {
            i.samples.reserve_exact(ROUND_RING_CAP - i.samples.len());
            grew = 1;
        }
        i.samples.clear();
        i.head = 0;
        i.dropped = 0;
        i.recorded_pivots = 0;
        i.recorded_weight = 0;
        i.prev_nel = 0;
        i.prev_claims = 0;
        i.prev_gc_nanos = 0;
        i.prev_rr_nanos = 0;
        grew
    }

    /// Record round `round`'s sample from the leader's cumulative
    /// counters. `pivots` is this round's eliminated supervariable
    /// count; everything else is differentiated against the previous
    /// call. The sweep nanos passed here are the cumulative total
    /// *before* this boundary's phase-E sweep runs, so a sweep's time
    /// lands on the **next** round's sample (see
    /// [`RoundSample::sweep_secs`]).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn note_round(
        &self,
        round: u32,
        pivots: u32,
        live_vars: u32,
        nel_now: usize,
        wtot: usize,
        claims_now: usize,
        gc_nanos_now: u64,
        rr_nanos_now: u64,
    ) {
        let mut i = self.inner.lock().unwrap();
        let weight = nel_now.saturating_sub(i.prev_nel) as u32;
        let sample = RoundSample {
            round,
            pivots,
            weight,
            live_vars,
            live_weight: wtot.saturating_sub(nel_now) as u32,
            claim_failures: claims_now.saturating_sub(i.prev_claims) as u32,
            gc_secs: gc_nanos_now.saturating_sub(i.prev_gc_nanos) as f64 / 1e9,
            sweep_secs: rr_nanos_now.saturating_sub(i.prev_rr_nanos) as f64 / 1e9,
        };
        i.prev_nel = nel_now;
        i.prev_claims = claims_now;
        i.prev_gc_nanos = gc_nanos_now;
        i.prev_rr_nanos = rr_nanos_now;
        i.recorded_pivots += u64::from(pivots);
        i.recorded_weight += u64::from(weight);
        if i.samples.len() < ROUND_RING_CAP {
            i.samples.push(sample);
        } else {
            let h = i.head;
            i.samples[h] = sample;
            i.head = (h + 1) % ROUND_RING_CAP;
            i.dropped += 1;
        }
    }

    /// Copy the retained samples (oldest first) into `stats`, then close
    /// the books: whatever the run eliminated outside the recorded
    /// rounds — the final phase-A exit, sweep-postponed pseudo-sets, the
    /// boundary GC/sweep time after the last sample — lands in a tail
    /// sample tagged `round == u32::MAX`, so Σ`pivots` = `total_pivots`
    /// and Σ`weight` = `wtot` exactly whenever nothing was dropped.
    pub(crate) fn fold_into(
        &mut self,
        stats: &mut OrderingStats,
        wtot: u64,
        total_pivots: u64,
        gc_nanos_end: u64,
        rr_nanos_end: u64,
    ) {
        let i = self.inner.get_mut().unwrap();
        stats.round_samples.clear();
        stats.round_samples.extend_from_slice(&i.samples[i.head..]);
        stats.round_samples.extend_from_slice(&i.samples[..i.head]);
        stats.round_samples_dropped = i.dropped;
        let pivots = total_pivots.saturating_sub(i.recorded_pivots);
        let weight = wtot.saturating_sub(i.recorded_weight);
        let gc_secs = gc_nanos_end.saturating_sub(i.prev_gc_nanos) as f64 / 1e9;
        let sweep_secs = rr_nanos_end.saturating_sub(i.prev_rr_nanos) as f64 / 1e9;
        if pivots > 0 || weight > 0 || gc_secs > 0.0 || sweep_secs > 0.0 {
            stats.round_samples.push(RoundSample {
                round: u32::MAX,
                pivots: pivots as u32,
                weight: weight as u32,
                live_vars: 0,
                live_weight: 0,
                claim_failures: 0,
                gc_secs,
                sweep_secs,
            });
        }
    }
}

/// All storage one ParAMD run needs, owned across runs. See the module
/// docs for the reuse rules.
pub struct ParAmdArena {
    pub(crate) sg: SharedGraph,
    pub(crate) aff: Affinity,
    /// Luby `l_min` array (round-stamped priorities; reset per run).
    pub(crate) lmin: Vec<AtomicU64>,
    /// Per-thread local minimum approximate degrees, cache-padded.
    pub(crate) lamds: Vec<CachePadded<AtomicUsize>>,
    /// Per-thread eliminated-this-round counts, cache-padded.
    pub(crate) sizes: Vec<CachePadded<AtomicUsize>>,
    pub(crate) progress_stall: AtomicUsize,
    /// The adapted relaxation factor, stored as `f64::to_bits` so any
    /// fractional (or huge) factor round-trips exactly — the old
    /// `(mult * 1e6) as usize` encoding quantized it lossily.
    pub(crate) adaptive_mult: AtomicU64,
    pub(crate) poison: AtomicBool,
    /// Set by the leader when the run's cancellation flag fired; the run
    /// exits at the next round boundary without assembling a result.
    pub(crate) abort: AtomicBool,
    pub(crate) gc_count: AtomicUsize,
    /// Cumulative stop-the-world nanoseconds spent in round-boundary GC.
    pub(crate) gc_nanos: AtomicU64,
    /// Mid-elimination re-reduction state (phase E).
    pub(crate) rereduce: RereduceState,
    /// Per-round telemetry ring (phase-D leader writes).
    pub(crate) round_log: RoundLog,
    pub(crate) set_sizes: Mutex<Vec<u32>>,
    pub(crate) slots: Vec<Mutex<ThreadSlot>>,
    // ---- assembly scratch (pooled like everything else) ----------------
    elim_order: Vec<i32>,
    parent_snap: Vec<i32>,
    rebuild: RebuildScratch,
    merge_cursor: Vec<usize>,
    pub(crate) result: OrderingResult,
    pub(crate) detail: ParAmdDetail,
    grow_events: u64,
    runs: u64,
}

impl Default for ParAmdArena {
    fn default() -> Self {
        Self::new()
    }
}

impl ParAmdArena {
    /// An empty arena; the first run sizes it, later runs reuse it.
    pub fn new() -> Self {
        Self {
            sg: SharedGraph::empty(),
            aff: Affinity::new(0),
            lmin: Vec::new(),
            lamds: Vec::new(),
            sizes: Vec::new(),
            progress_stall: AtomicUsize::new(0),
            adaptive_mult: AtomicU64::new(0),
            poison: AtomicBool::new(false),
            abort: AtomicBool::new(false),
            gc_count: AtomicUsize::new(0),
            gc_nanos: AtomicU64::new(0),
            rereduce: RereduceState::new(),
            round_log: RoundLog::new(),
            set_sizes: Mutex::new(Vec::new()),
            slots: Vec::new(),
            elim_order: Vec::new(),
            parent_snap: Vec::new(),
            rebuild: RebuildScratch::default(),
            merge_cursor: Vec::new(),
            result: OrderingResult::new(Vec::new()),
            detail: ParAmdDetail::default(),
            grow_events: 0,
            runs: 0,
        }
    }

    /// Number of times any pooled buffer had to grow. Stays flat across
    /// warm runs whose graphs fit the retained storage — the test hook
    /// behind the "warm path performs no O(n)/O(nnz) allocations" claim.
    pub fn grow_events(&self) -> u64 {
        self.grow_events
    }

    /// Runs served by this arena so far.
    pub fn runs(&self) -> u64 {
        self.runs
    }

    /// Retained slab size in words — the dominant share of this arena's
    /// memory and the key the pool's eviction policy ranks by.
    pub fn slab_words(&self) -> usize {
        self.sg.iw.len()
    }

    /// The pooled result of the most recent run.
    pub fn result(&self) -> &OrderingResult {
        &self.result
    }

    /// The pooled per-run detail of the most recent run.
    pub fn detail(&self) -> &ParAmdDetail {
        &self.detail
    }

    /// Move the most recent run's outputs out of the pool (the cold-path
    /// convenience; warm callers should read [`Self::result`] instead and
    /// copy only what they need to keep).
    pub fn take_results(&mut self) -> (OrderingResult, ParAmdDetail) {
        (
            std::mem::replace(&mut self.result, OrderingResult::new(Vec::new())),
            std::mem::take(&mut self.detail),
        )
    }

    /// Reset every pooled structure for a run of `t` threads over `g`,
    /// growing only what doesn't fit. `weights` seeds supervariables
    /// (`nv > 1`, the reduction layer's twin classes); `None` is the
    /// ordinary unweighted setup.
    pub(crate) fn prepare(
        &mut self,
        g: &SymGraph,
        cfg: &ParAmd,
        t: usize,
        weights: Option<&[i32]>,
    ) {
        let n = g.n;
        self.runs += 1;
        let mut grew = u64::from(self.sg.reset_from_weighted(g, cfg.elbow, weights));
        grew += u64::from(self.aff.reset(n));
        // Degree ceiling / empty sentinel: total column weight.
        let wtot = self.sg.weight;
        if self.lmin.len() < n {
            self.lmin.resize_with(n, || AtomicU64::new(u64::MAX));
            grew += 1;
        }
        for l in &self.lmin[..n] {
            l.store(u64::MAX, Relaxed);
        }
        if self.lamds.len() < t {
            self.lamds
                .resize_with(t, || CachePadded(AtomicUsize::new(0)));
            self.sizes
                .resize_with(t, || CachePadded(AtomicUsize::new(0)));
            grew += 1;
        }
        for a in &self.lamds[..t] {
            a.store(wtot, Relaxed);
        }
        for s in &self.sizes[..t] {
            s.store(0, Relaxed);
        }
        self.progress_stall.store(0, Relaxed);
        self.adaptive_mult.store(cfg.mult.to_bits(), Relaxed);
        self.poison.store(false, Relaxed);
        self.abort.store(false, Relaxed);
        self.gc_count.store(0, Relaxed);
        self.gc_nanos.store(0, Relaxed);
        grew += u64::from(self.rereduce.reset(n));
        grew += u64::from(self.round_log.reset());
        self.set_sizes.get_mut().unwrap().clear();
        while self.slots.len() < t {
            let tid = self.slots.len();
            self.slots.push(Mutex::new(ThreadSlot::new(tid)));
            grew += 1;
        }
        // Expected per-thread elimination-log share: totals are bounded by
        // n pivots across all threads; the slack absorbs mild imbalance.
        let log_hint = (n / t + n / (4 * t).max(1) + 64).min(n);
        for slot in self.slots[..t].iter_mut() {
            grew += u64::from(slot.get_mut().unwrap().reset(n, wtot, log_hint, cfg.seed));
        }
        self.elim_order.clear();
        self.grow_events += grew;
        // Clear the pooled outputs (keeping capacity) so an early return —
        // e.g. the empty graph — reads as an empty result.
        self.result.perm.clear();
        self.result.iperm.clear();
        self.result.phases = PhaseTimes::default();
        let stats = &mut self.result.stats;
        stats.rounds = 0;
        stats.pivots = 0;
        stats.gc_count = 0;
        stats.gc_secs = 0.0;
        stats.mid_twins_merged = 0;
        stats.mid_dense_postponed = 0;
        stats.elements_absorbed = 0;
        stats.rereduce_count = 0;
        stats.rereduce_secs = 0.0;
        stats.work_words = 0;
        stats.modeled_time = 0.0;
        stats.set_sizes.clear();
        stats.thread_work.clear();
        stats.round_samples.clear();
        stats.round_samples_dropped = 0;
        stats.claim_failures = 0;
        if n == 0 {
            // Only the empty-graph early return skips `assemble`, which
            // otherwise rebuilds the detail in place (reusing the
            // `round_work` rows' capacity — don't drop them here).
            let d = &mut self.detail;
            d.round_work.clear();
            d.set_sizes.clear();
            d.select_secs.clear();
            d.elim_secs.clear();
            d.model_speedup = 0.0;
        }
    }

    /// Merge the per-thread logs and assemble the pooled result/detail.
    ///
    /// The elimination order is `(round, tid, local order)` — the same
    /// deterministic order the old 4-tuple sort produced, but obtained by
    /// walking each thread's (already round-sorted) log once per round,
    /// without materializing a tuple per pivot.
    pub(crate) fn assemble(&mut self, t: usize, total_secs: f64) {
        let n = self.sg.n;
        let mut rounds = 0usize;
        let mut logged = 0usize;
        for slot in self.slots[..t].iter_mut() {
            let s = slot.get_mut().unwrap();
            rounds = rounds.max(s.ws.work_log.len());
            logged += s.elim_log.len();
        }

        self.elim_order.clear();
        self.merge_cursor.clear();
        self.merge_cursor.resize(t, 0);
        for r in 0..rounds as u32 {
            for (tid, slot) in self.slots[..t].iter_mut().enumerate() {
                let s = slot.get_mut().unwrap();
                let c = &mut self.merge_cursor[tid];
                while *c < s.elim_log.len() && s.elim_log[*c].0 == r {
                    self.elim_order.push(s.elim_log[*c].1);
                    *c += 1;
                }
            }
        }
        debug_assert_eq!(self.elim_order.len(), logged, "log merge lost pivots");
        // Rows the re-reduction sweep postponed come last: they are their
        // own roots (parent -1, nv kept), exactly the pre-ordering dense
        // rule's tail placement, so appending them after every logged
        // pivot yields the same permutation shape mid-run.
        self.elim_order
            .append(self.rereduce.postponed.get_mut().unwrap());

        self.parent_snap.clear();
        self.parent_snap.resize(n, -1);
        for (v, p) in self.parent_snap.iter_mut().enumerate() {
            *p = self.sg.parent[v].load(Relaxed);
        }
        rebuild_perm_into(
            n,
            &self.elim_order,
            &self.parent_snap,
            &mut self.rebuild,
            &mut self.result.perm,
        );
        invert_perm_into(&self.result.perm, &mut self.result.iperm);

        // Detail: per-round per-thread work matrix, reusing row capacity.
        let d = &mut self.detail;
        if d.round_work.len() < rounds {
            d.round_work.resize_with(rounds, Vec::new);
        }
        d.round_work.truncate(rounds);
        for row in d.round_work.iter_mut() {
            row.clear();
            row.resize(t, RoundWork::default());
        }
        for (tid, slot) in self.slots[..t].iter_mut().enumerate() {
            let s = slot.get_mut().unwrap();
            for (r, w) in s.ws.work_log.iter().enumerate() {
                d.round_work[r][tid] = *w;
            }
        }
        d.set_sizes.clone_from(self.set_sizes.get_mut().unwrap());
        d.select_secs.clear();
        d.elim_secs.clear();
        for slot in self.slots[..t].iter_mut() {
            let s = slot.get_mut().unwrap();
            d.select_secs.push(s.select_secs);
            d.elim_secs.push(s.elim_secs);
        }
        d.model_speedup = cost::model_speedup(&d.round_work, cost::DEFAULT_BARRIER_COST);

        // Stats + phases on the pooled result.
        let stats = &mut self.result.stats;
        stats.rounds = rounds as u64;
        stats.pivots = self.elim_order.len() as u64;
        stats.set_sizes.clone_from(&d.set_sizes);
        stats.gc_count = self.gc_count.load(Relaxed) as u64;
        stats.gc_secs = self.gc_nanos.load(Relaxed) as f64 / 1e9;
        stats.mid_twins_merged = self.rereduce.twins.load(Relaxed) as u64;
        stats.mid_dense_postponed = self.rereduce.dense.load(Relaxed) as u64;
        stats.elements_absorbed = self.rereduce.absorbed.load(Relaxed) as u64;
        stats.rereduce_count = self.rereduce.passes.load(Relaxed) as u64;
        stats.rereduce_secs = self.rereduce.nanos.load(Relaxed) as f64 / 1e9;
        stats.claim_failures = self.sg.claim_failures.load(Relaxed) as u64;
        let (wtot, pivots) = (self.sg.weight as u64, stats.pivots);
        self.round_log.fold_into(
            stats,
            wtot,
            pivots,
            self.gc_nanos.load(Relaxed),
            self.rereduce.nanos.load(Relaxed),
        );
        stats.work_words = d
            .round_work
            .iter()
            .flatten()
            .map(|w| w.select + w.elim)
            .sum();
        stats.thread_work.clear();
        for slot in self.slots[..t].iter_mut() {
            let s = slot.get_mut().unwrap();
            stats.thread_work.push(vec![
                s.ws.work_log.iter().map(|w| w.select).sum::<u64>(),
                s.ws.work_log.iter().map(|w| w.elim).sum::<u64>(),
            ]);
        }
        let select_total: f64 = d.select_secs.iter().sum();
        let elim_total: f64 = d.elim_secs.iter().sum();
        stats.modeled_time = if d.model_speedup > 0.0 {
            (select_total + elim_total) / d.model_speedup
        } else {
            0.0
        };
        self.result.phases = PhaseTimes::default();
        self.result.phases.add("select", select_total);
        self.result.phases.add("core", elim_total);
        self.result
            .phases
            .add("other", (total_secs - select_total - elim_total).max(0.0));
    }
}

/// A bounded checkout pool of arenas for concurrent request handlers:
/// `acquire` pops a warm arena (preferring the largest slab), creates a
/// fresh one while under [`Self::capacity`], and otherwise **blocks**
/// until a release — pool exhaustion is backpressure, not growth. Idle
/// arenas over capacity are evicted LRU-by-slab-size (smallest slab
/// first, stalest first among equals).
pub struct ArenaPool {
    inner: Mutex<PoolInner>,
    /// Signalled on release and on capacity raises.
    freed: Condvar,
}

struct IdleArena {
    arena: ParAmdArena,
    /// Monotone release tick; smaller = less recently used.
    last_used: u64,
}

struct PoolInner {
    idle: Vec<IdleArena>,
    /// Arenas currently checked out.
    outstanding: usize,
    /// Max arenas alive (idle + outstanding).
    cap: usize,
    tick: u64,
    evictions: u64,
}

impl Default for ArenaPool {
    fn default() -> Self {
        Self::new()
    }
}

impl ArenaPool {
    /// An unbounded pool (the single-tenant default).
    pub fn new() -> Self {
        Self::bounded(usize::MAX)
    }

    /// A pool holding at most `cap` arenas alive (minimum 1).
    pub fn bounded(cap: usize) -> Self {
        Self {
            inner: Mutex::new(PoolInner {
                idle: Vec::new(),
                outstanding: 0,
                cap: cap.max(1),
                tick: 0,
                evictions: 0,
            }),
            freed: Condvar::new(),
        }
    }

    /// Max arenas alive (idle + checked out).
    pub fn capacity(&self) -> usize {
        self.inner.lock().unwrap().cap
    }

    /// Re-bound the pool. Shrinking evicts surplus idle arenas
    /// immediately; raising wakes blocked acquirers.
    pub fn set_capacity(&self, cap: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.cap = cap.max(1);
        Self::evict_over_cap(&mut inner);
        drop(inner);
        self.freed.notify_all();
    }

    /// Check an arena out — the warmest (largest-slab) idle arena if one
    /// is available, a fresh one while under capacity, and otherwise
    /// blocks until a release frees a slot.
    pub fn acquire(&self) -> ParAmdArena {
        let mut inner = self.inner.lock().unwrap();
        loop {
            if let Some(arena) = Self::pop_warmest(&mut inner) {
                inner.outstanding += 1;
                return arena;
            }
            if inner.outstanding < inner.cap {
                inner.outstanding += 1;
                return ParAmdArena::new();
            }
            inner = self.freed.wait(inner).unwrap();
        }
    }

    /// [`Self::acquire`] wrapped in an RAII guard that releases on drop
    /// (including on unwind, so a panicking request can't strand the
    /// pool's capacity accounting).
    ///
    /// The [`failpoint::ARENA_CHECKOUT`] hook fires *before* the acquire
    /// — an injected allocation failure panics with no arena checked
    /// out, so the chaos suite can prove exhaustion never corrupts the
    /// pool's accounting.
    pub fn checkout(&self) -> PooledArena<'_> {
        failpoint::hit(failpoint::ARENA_CHECKOUT);
        PooledArena {
            pool: self,
            arena: Some(self.acquire()),
        }
    }

    /// Return an arena previously checked out with [`Self::acquire`] /
    /// [`Self::checkout`]. Releasing an arena the pool never handed out
    /// corrupts the capacity accounting — use [`Self::seed`] to insert
    /// externally-built arenas instead.
    pub fn release(&self, arena: ParAmdArena) {
        let mut inner = self.inner.lock().unwrap();
        debug_assert!(inner.outstanding > 0, "release without a matching acquire");
        inner.outstanding = inner.outstanding.saturating_sub(1);
        inner.tick += 1;
        let last_used = inner.tick;
        inner.idle.push(IdleArena { arena, last_used });
        Self::evict_over_cap(&mut inner);
        drop(inner);
        self.freed.notify_all();
    }

    /// Insert an externally-built (e.g. pre-warmed) arena as idle
    /// inventory, subject to the same capacity bound and eviction policy
    /// — unlike [`Self::release`], no checkout is decremented.
    pub fn seed(&self, arena: ParAmdArena) {
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let last_used = inner.tick;
        inner.idle.push(IdleArena { arena, last_used });
        Self::evict_over_cap(&mut inner);
        drop(inner);
        self.freed.notify_all();
    }

    /// Number of idle arenas currently pooled.
    pub fn idle(&self) -> usize {
        self.inner.lock().unwrap().idle.len()
    }

    /// Number of arenas currently checked out.
    pub fn outstanding(&self) -> usize {
        self.inner.lock().unwrap().outstanding
    }

    /// Arenas dropped by the eviction policy so far.
    pub fn evictions(&self) -> u64 {
        self.inner.lock().unwrap().evictions
    }

    /// Best arena to reuse: largest slab (most retained elbow, least
    /// chance of growing), most recently used among equals.
    fn pop_warmest(inner: &mut PoolInner) -> Option<ParAmdArena> {
        let i = inner
            .idle
            .iter()
            .enumerate()
            .max_by_key(|(_, e)| (e.arena.slab_words(), e.last_used))
            .map(|(i, _)| i)?;
        Some(inner.idle.swap_remove(i).arena)
    }

    /// Drop idle arenas until the alive set fits the cap: smallest slab
    /// first (cheapest to rebuild), least recently used among equals.
    fn evict_over_cap(inner: &mut PoolInner) {
        while inner.idle.len() + inner.outstanding > inner.cap && !inner.idle.is_empty() {
            let i = inner
                .idle
                .iter()
                .enumerate()
                .min_by_key(|(_, e)| (e.arena.slab_words(), e.last_used))
                .map(|(i, _)| i)
                .expect("non-empty idle list");
            inner.idle.swap_remove(i);
            inner.evictions += 1;
        }
    }
}

/// An arena checked out of an [`ArenaPool`], returned on drop.
pub struct PooledArena<'a> {
    pool: &'a ArenaPool,
    arena: Option<ParAmdArena>,
}

impl std::ops::Deref for PooledArena<'_> {
    type Target = ParAmdArena;
    fn deref(&self) -> &ParAmdArena {
        self.arena.as_ref().expect("arena present until drop")
    }
}

impl std::ops::DerefMut for PooledArena<'_> {
    fn deref_mut(&mut self) -> &mut ParAmdArena {
        self.arena.as_mut().expect("arena present until drop")
    }
}

impl Drop for PooledArena<'_> {
    fn drop(&mut self) {
        if let Some(arena) = self.arena.take() {
            self.pool.release(arena);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cache_padding_isolates_counters() {
        assert!(std::mem::align_of::<CachePadded<AtomicUsize>>() >= 128);
        assert!(std::mem::size_of::<CachePadded<AtomicUsize>>() >= 128);
        let v: Vec<CachePadded<AtomicUsize>> = (0..4)
            .map(|_| CachePadded(AtomicUsize::new(0)))
            .collect();
        let a = &v[0].0 as *const _ as usize;
        let b = &v[1].0 as *const _ as usize;
        assert!(b - a >= 128, "adjacent counters must not share a line");
    }

    #[test]
    fn adaptive_mult_roundtrips_fractional_factors_exactly() {
        use crate::matgen::mesh2d;
        // 1.1 has no finite binary expansion; the old `(mult * 1e6) as
        // usize` encoding truncated it. `f64::to_bits` must round-trip
        // any factor bit-exactly, including large ones.
        let g = mesh2d(4, 4);
        let mut a = ParAmdArena::new();
        for mult in [1.1f64, 1.000000123, 3.5e9, f64::MIN_POSITIVE] {
            a.prepare(&g, &ParAmd::new(1).with_mult(mult), 1, None);
            let back = f64::from_bits(a.adaptive_mult.load(Relaxed));
            assert_eq!(back.to_bits(), mult.to_bits(), "mult {mult} mangled");
        }
    }

    #[test]
    fn pool_checkout_roundtrip() {
        let pool = ArenaPool::new();
        assert_eq!(pool.idle(), 0);
        let a = pool.acquire();
        let b = pool.acquire();
        assert_eq!(pool.outstanding(), 2);
        pool.release(a);
        pool.release(b);
        assert_eq!(pool.idle(), 2);
        let _c = pool.acquire();
        assert_eq!(pool.idle(), 1);
    }

    #[test]
    fn bounded_pool_blocks_at_capacity_until_release() {
        use std::sync::atomic::AtomicBool;
        let pool = ArenaPool::bounded(1);
        let only = pool.acquire();
        let got_second = AtomicBool::new(false);
        std::thread::scope(|s| {
            let pool = &pool;
            let got_second = &got_second;
            s.spawn(move || {
                let a = pool.acquire(); // must block until the release below
                got_second.store(true, Relaxed);
                pool.release(a);
            });
            std::thread::sleep(std::time::Duration::from_millis(30));
            assert!(
                !got_second.load(Relaxed),
                "acquire must block while the pool is exhausted"
            );
            pool.release(only);
        });
        assert!(got_second.load(Relaxed));
        assert_eq!(pool.outstanding(), 0);
        assert_eq!(pool.idle(), 1);
    }

    /// An arena warmed on `g` so its slab has a graph-dependent size.
    fn warmed(g: &SymGraph) -> ParAmdArena {
        let mut a = ParAmdArena::new();
        a.prepare(g, &ParAmd::new(1), 1, None);
        a
    }

    #[test]
    fn shrinking_capacity_evicts_smallest_slab_first() {
        use crate::matgen::mesh2d;
        let small = warmed(&mesh2d(4, 4));
        let big = warmed(&mesh2d(12, 12));
        assert!(big.slab_words() > small.slab_words());
        let big_slab = big.slab_words();

        let pool = ArenaPool::bounded(2);
        pool.seed(small);
        pool.seed(big);
        assert_eq!(pool.idle(), 2);

        pool.set_capacity(1);
        assert_eq!(pool.idle(), 1, "one idle arena must be evicted");
        assert_eq!(pool.evictions(), 1);
        let survivor = pool.acquire();
        assert_eq!(
            survivor.slab_words(),
            big_slab,
            "the big warm slab must survive eviction"
        );
    }

    #[test]
    fn checkout_guard_releases_on_drop() {
        let pool = ArenaPool::bounded(1);
        {
            let _guard = pool.checkout();
            assert_eq!(pool.outstanding(), 1);
        }
        assert_eq!(pool.outstanding(), 0);
        assert_eq!(pool.idle(), 1);
    }
}
