//! Component→shard routing: deterministic size-classed placement.
//!
//! Shards are *size-classed*: shard 0 is the **wide** runtime (most
//! worker threads), the rest are **narrow**. Routing works in estimated
//! finish time — a shard's queued vertex load divided by its thread
//! count — so a narrow shard is only preferred when it genuinely
//! finishes the job earlier:
//!
//! - [`plan`] places the components of a decomposed request: the largest
//!   component is pinned to the wide shard (it dominates the critical
//!   path and deserves the widest pool), the rest follow the classic
//!   largest-first greedy (LPT) onto the shard with the least estimated
//!   finish time, ties to the lowest shard id.
//! - [`pick_shard`] places a whole connected request on the least-loaded
//!   shard, so *concurrent* requests spread across shards instead of
//!   serializing behind one runtime.
//!
//! Both are pure functions of their load snapshot, so placement is
//! deterministic and unit-testable.

/// Estimated finish time of putting `n` more vertices on a shard.
fn finish_time(load: f64, n: usize, threads: usize) -> f64 {
    load + n as f64 / threads.max(1) as f64
}

/// Least-finish-time shard for one connected graph of `n` vertices.
/// `loads[s]` is shard `s`'s pending+active vertex count.
pub fn pick_shard(n: usize, loads: &[u64], threads: &[usize]) -> usize {
    debug_assert_eq!(loads.len(), threads.len());
    debug_assert!(!threads.is_empty());
    let mut best = 0usize;
    let mut best_cost = f64::INFINITY;
    for s in 0..threads.len() {
        let cost = finish_time(loads[s] as f64 / threads[s].max(1) as f64, n, threads[s]);
        if cost < best_cost {
            best_cost = cost;
            best = s;
        }
    }
    best
}

/// Assign the components of one request to shards. `sizes` must be
/// ascending (component-id order, as [`crate::graph::connected_components`]
/// produces); the returned vector maps component id → shard id.
pub fn plan(sizes: &[usize], loads: &[u64], threads: &[usize]) -> Vec<usize> {
    let shards = threads.len();
    debug_assert!(shards > 0);
    let mut assign = vec![0usize; sizes.len()];
    if sizes.is_empty() || shards == 1 {
        return assign;
    }
    let mut load: Vec<f64> = loads
        .iter()
        .zip(threads)
        .map(|(&l, &t)| l as f64 / t.max(1) as f64)
        .collect();
    // `sizes` ascends, so walking it backwards is the deterministic
    // largest-first schedule.
    for (k, c) in (0..sizes.len()).rev().enumerate() {
        let s = if k == 0 {
            0 // size-classing: the largest component gets the wide shard
        } else {
            let mut best = 0usize;
            let mut best_cost = f64::INFINITY;
            for s in 0..shards {
                let cost = finish_time(load[s], sizes[c], threads[s]);
                if cost < best_cost {
                    best_cost = cost;
                    best = s;
                }
            }
            best
        };
        assign[c] = s;
        load[s] += sizes[c] as f64 / threads[s].max(1) as f64;
    }
    assign
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn largest_component_lands_on_the_wide_shard() {
        // Ascending sizes; the last (largest) must go to shard 0 even
        // though shard 0 is already the most loaded.
        let assign = plan(&[10, 20, 1000], &[500, 0, 0], &[8, 2, 2]);
        assert_eq!(assign[2], 0);
    }

    #[test]
    fn equal_components_spread_over_equal_shards() {
        let assign = plan(&[100, 100, 100, 100], &[0, 0, 0, 0], &[2, 2, 2, 2]);
        let mut sorted = assign.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3], "one component per shard");
    }

    #[test]
    fn plan_is_deterministic() {
        let a = plan(&[5, 9, 9, 40], &[3, 0, 7], &[4, 2, 2]);
        let b = plan(&[5, 9, 9, 40], &[3, 0, 7], &[4, 2, 2]);
        assert_eq!(a, b);
    }

    #[test]
    fn single_shard_takes_everything() {
        assert_eq!(plan(&[1, 2, 3], &[9], &[4]), vec![0, 0, 0]);
    }

    #[test]
    fn pick_shard_prefers_idle_over_loaded() {
        assert_eq!(pick_shard(100, &[1000, 0], &[4, 4]), 1);
        // All idle: the wide shard wins (fastest estimated finish).
        assert_eq!(pick_shard(100, &[0, 0], &[4, 2]), 0);
    }

    #[test]
    fn pick_shard_accounts_for_width() {
        // Same load, but shard 0 is twice as wide — it finishes earlier.
        assert_eq!(pick_shard(500, &[400, 400], &[8, 4]), 0);
    }
}
