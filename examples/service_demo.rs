//! Coordinator service demo: a stream of mixed ordering requests through
//! the `Service` pipeline with metrics reporting — the deployable-
//! component view of the library. The service owns one persistent ParAMD
//! worker pool and a bounded pool of reusable arenas, so repeated ParAMD
//! requests run spawn-free and allocation-free (warm path). Sections:
//! synchronous requests (the submit+wait shim), a solve request, the
//! warm-up effect on latency, an **async ticket burst** through the
//! bounded queue showing the wait-vs-service latency split, a
//! **sharded ordering engine** decomposing a disconnected request into
//! component jobs that run concurrently across independent runtimes,
//! the **result cache** replaying repeated graphs — and repeated
//! components under scattered labels — without re-running ParAMD, and
//! the **telemetry** view of one request: its flight-recorder trace
//! (submit → fetch the ticket's `RequestTrace` → render Chrome
//! trace-event JSON), the per-round elimination samples in the reply,
//! and the Prometheus exposition of the service metrics. Then the
//! **failure modes & overload behavior**: admission control shedding a
//! burst past the in-flight budget, a dead-on-arrival deadline
//! resolving to a typed error instead of running, and quality-shedding
//! ordering small components inline under pressure. The final section
//! shows **persistence**: the crash-safe on-disk cache tier surviving
//! a service restart — the cold pass appends checksummed record frames
//! write-behind, the reopened service warm-starts from recovery
//! (snapshot → log replay, torn tails truncated, corrupt records
//! quarantined and counted) and answers the repeat from verified hits.
//!
//! Run: `cargo run --release --example service_demo`

use paramd::coordinator::{Method, OrderRequest, Service, SolveSpec, SubmitOptions};
use paramd::matgen::{self, Scale};

fn main() {
    let svc = Service::new(2)
        .with_scheduler_threads(2)
        .with_arena_cap(2)
        .with_queue_cap(16);
    let suite = matgen::suite();

    println!("== ordering requests ==");
    for i in 0..10 {
        let e = &suite[i % suite.len()];
        let g = (e.gen)(Scale::Tiny);
        let method = match i % 3 {
            0 => Method::Amd,
            1 => Method::ParAmd {
                threads: 4,
                mult: 1.1,
                lim_total: 8192,
            },
            _ => Method::Nd,
        };
        let rep = svc.order(&OrderRequest {
            matrix: Some(matgen::spd_from_graph(&g, 1.0)),
            pattern: None,
            method,
            compute_fill: true,
        });
        println!(
            "  {:<14} {:<7} n={:<6} {:.4}s fill={:.2e}",
            e.name,
            method.name(),
            rep.perm.len(),
            rep.total_secs,
            rep.fill_in.unwrap() as f64
        );
    }

    println!("\n== solve request (native dense tail) ==");
    let a = matgen::spd_from_graph(&(suite[0].gen)(Scale::Tiny), 1.0);
    let rep = svc
        .solve(
            &OrderRequest {
                matrix: Some(a),
                pattern: None,
                method: Method::ParAmd {
                    threads: 4,
                    mult: 1.1,
                    lim_total: 8192,
                },
                compute_fill: false,
            },
            &SolveSpec::OnesSolution,
        )
        .unwrap();
    println!(
        "  residual={:.2e} factor={:.3}s solve={:.3}s engine={}",
        rep.residual, rep.factor_secs, rep.solve_secs, rep.engine
    );

    println!("\n== warm path: repeated ParAMD requests on one graph ==");
    let g = (suite[0].gen)(Scale::Tiny);
    let warm_req = OrderRequest {
        matrix: None,
        pattern: Some(g.clone()),
        method: Method::ParAmd {
            threads: 4,
            mult: 1.1,
            lim_total: 8192,
        },
        compute_fill: false,
    };
    for i in 0..5 {
        let rep = svc.order(&warm_req);
        println!(
            "  request {i}: {:.5}s ({})",
            rep.order_secs,
            if i == 0 {
                "cold — arena sized here"
            } else {
                "warm — pooled arena, parked workers"
            }
        );
    }
    println!("  idle arenas pooled: {}", svc.idle_arenas());

    println!("\n== async pipeline: a burst of tickets ==");
    // Submit first, wait later: the queue absorbs the burst (bounded —
    // submit would block at capacity) while the schedulers drain it.
    let mut tickets = Vec::new();
    for i in 0..8 {
        let e = &suite[i % suite.len()];
        let g = (e.gen)(Scale::Tiny);
        tickets.push((
            e.name,
            svc.submit(OrderRequest {
                matrix: None,
                pattern: Some(g),
                method: Method::ParAmd {
                    threads: 4,
                    mult: 1.1,
                    lim_total: 8192,
                },
                compute_fill: false,
            }),
        ));
    }
    println!("  8 tickets submitted; queue depth now {}", svc.queue_depth());
    for (name, ticket) in tickets {
        let rep = ticket.wait();
        println!("  {:<14} n={:<6} {:.5}s", name, rep.perm.len(), rep.order_secs);
    }
    let m = svc.metrics();
    println!(
        "  queue peak {} | cancelled {} | arena evictions {} | idle arenas {}",
        m.pipeline.queue_depth_peak,
        m.pipeline.cancelled,
        m.pipeline.arena_evictions,
        svc.idle_arenas()
    );

    println!("\n== sharded ordering: components across independent runtimes ==");
    // A disconnected request splits into per-component jobs; with 2
    // shards (one wide, one narrow) the components order concurrently
    // and the permutations stitch back in ascending-size order. A batch
    // of follow-up requests goes through `submit_all` (one queue
    // reservation), each bounded by a `wait_deadline`.
    let sharded = Service::new(2).with_shards(2).with_shard_threads(2);
    let g = paramd::matgen::multi_component(6, &[400, 150, 250]);
    let req = OrderRequest {
        matrix: None,
        pattern: Some(g.clone()),
        method: Method::ParAmd {
            threads: 2,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    };
    let rep = sharded.order(&req);
    println!(
        "  {} vertices / 6 components through 2 shards: {:.5}s",
        g.n, rep.order_secs
    );
    let batch: Vec<OrderRequest> = (0..4).map(|_| req.clone()).collect();
    let tickets = sharded.submit_all(batch);
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait_deadline(std::time::Duration::from_secs(30)) {
            Ok(r) => println!("  batch request {i}: n={} ok", r.perm.len()),
            Err(e) => println!("  batch request {i}: {e}"),
        }
    }
    let sm = sharded.metrics().shards;
    println!("  {}", sm.report().trim_end().replace('\n', "\n  "));

    println!("\n== result cache: repeated orderings without re-running ParAMD ==");
    // The cache (on by default, 64 MiB; tune with `with_result_cache` /
    // `--cache-mb`, disable with 0 / `--no-cache`) fingerprints every
    // graph it orders. An exact repeat of a connected request replays
    // its permutation before reduction even runs, and — the FEM-assembly
    // pattern — requests whose *components* repeat under different
    // vertex scatters hit per component: zero router/runtime/arena work.
    let cached = Service::new(2).with_shards(2).with_shard_threads(2);
    for round in 0..2 {
        // Same component population, different scatter per request.
        let g = paramd::matgen::repeated_components_seeded(3, 300, 2, round);
        let rep = cached.order(&OrderRequest {
            matrix: None,
            pattern: Some(g),
            method: Method::ParAmd {
                threads: 2,
                mult: 1.1,
                lim_total: 0,
            },
            compute_fill: false,
        });
        println!(
            "  request {round}: n={} in 6 components, {:.5}s ({})",
            rep.perm.len(),
            rep.order_secs,
            if round == 0 {
                "cold — components ordered and cached"
            } else {
                "hot — every component served from the cache"
            }
        );
    }
    let cm = cached.metrics().cache;
    println!(
        "  cache: hits={} misses={} entries={} bytes={} saved~={:.4}s",
        cm.hits, cm.misses, cm.entries, cm.bytes, cm.saved_secs
    );

    println!("\n== hybrid ND x ParAMD: one connected mesh across shards ==");
    // Component decomposition finds nothing to split in one huge
    // connected mesh — the worst case for the shard engine. With
    // `with_hybrid` (CLI: `--hybrid`, `--partition-threshold`,
    // `--recursion-depth`, `--balance-factor`) the engine cuts it by
    // nested dissection into independent subdomains that order in
    // parallel across the shards, then orders the vertex separators
    // last and stitches one valid permutation.
    let hybrid = Service::new(2).with_shards(4).with_shard_threads(1).with_hybrid(
        paramd::coordinator::HybridConfig {
            enabled: true,
            partition_threshold: 2_000,
            recursion_depth: 2,
            balance_factor: 1.3,
        },
    );
    let mesh = paramd::matgen::mesh2d(70, 70);
    let rep = hybrid.order(&OrderRequest {
        matrix: None,
        pattern: Some(mesh.clone()),
        method: Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    });
    let hm = hybrid.metrics().shards;
    println!(
        "  {} vertices, 1 connected component -> {} subdomain jobs + {} separator \
         blocks ({:.1}% separator vertices) in {:.5}s",
        mesh.n,
        hm.subdomains,
        hm.separators,
        100.0 * hm.separator_frac(),
        rep.order_secs
    );
    println!("  {}", hm.report().trim_end().replace('\n', "\n  "));

    println!("\n== mid-elimination re-reduction: the sweep at round boundaries ==");
    // The pre-ordering reduction layer runs once, up front — but graphs
    // grow *new* twins and dense rows as elimination retires their
    // distinguishing structure. `matgen::emergent_twins` is built so no
    // two vertices start as twins, yet whole classes collapse once the
    // early elimination waves die. The sweep (CLI: `--no-rereduce`,
    // `--rereduce-every`, `--rereduce-elbow`; on by default, cadence 4)
    // re-detects twins globally, absorbs subsumed elements, and
    // re-postpones rows gone dense — here at cadence 1 to make every
    // round boundary count.
    let sweeping = Service::new(2).with_rereduce_every(1);
    let etg = paramd::matgen::emergent_twins(1400, 3);
    let rep = sweeping.order(&OrderRequest {
        matrix: None,
        pattern: Some(etg.clone()),
        method: Method::ParAmd {
            threads: 2,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    });
    let sm = sweeping.metrics().shards;
    println!(
        "  {} vertices, zero twins at submit -> {} sweeps merged {} mid-flight \
         twins, absorbed {} elements, re-postponed {} rows ({:.5}s in-sweep, \
         {:.5}s total)",
        etg.n,
        sm.rereduce_passes,
        sm.mid_twins_merged,
        sm.elements_absorbed,
        sm.mid_dense_postponed,
        sm.rereduce_secs,
        rep.order_secs
    );

    println!("\n== telemetry: one request's flight recorder and round samples ==");
    // Every ticket carries a `RequestTrace`. Grab it before waiting,
    // then read the spans after the reply lands: queued/preprocess/
    // order/fill on the pipeline lane, cc-split/reduce/cache-probe/
    // route/stitch on the engine lane, dispatch/elimination per shard.
    // `to_chrome_json()` renders the whole thing for Perfetto; a
    // `Service::with_trace_dump(dir, slow_ms)` sink does this
    // automatically for slow requests (CLI: `--trace-dir`,
    // `--trace-slow-ms`).
    let traced = Service::new(2);
    let tg = paramd::matgen::mesh2d(40, 40);
    let treq = OrderRequest {
        matrix: None,
        pattern: Some(tg.clone()),
        method: Method::ParAmd {
            threads: 2,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: true,
    };
    let ticket = traced.submit(treq);
    let trace = ticket.trace();
    let rep = ticket.wait();
    println!(
        "  req {}: {} spans cover {:.1}% of the wall",
        trace.id(),
        trace.spans().len(),
        100.0 * trace.coverage()
    );
    for s in trace.spans() {
        println!("    lane {} {:<14} +{:>6}us {:>6}us", s.lane, s.name, s.start_us, s.dur_us);
    }
    println!(
        "  chrome trace-event JSON: {} bytes (load in Perfetto)",
        trace.to_chrome_json().len()
    );
    // The reply's round samples are the paper's Fig-4 decay curve: per
    // outer round, pivots retired, live vertices/weight remaining, and
    // the claim-failure (memory contention) count.
    println!("  {} elimination rounds sampled:", rep.round_samples.len());
    for s in rep.round_samples.iter().take(4) {
        println!(
            "    round {:>2}: pivots={:<5} live_vars={:<6} claim_failures={}",
            s.round, s.pivots, s.live_vars, s.claim_failures
        );
    }
    // Fixed-footprint exposition: the same `Metrics` snapshot renders as
    // a Prometheus text page (or `export::json_snapshot`) — latency
    // quantiles come from log-bucketed histograms, so memory stays
    // constant no matter how many requests flow.
    let page = paramd::telemetry::export::prometheus(&traced.metrics());
    let shown: Vec<&str> = page.lines().filter(|l| !l.starts_with('#')).take(6).collect();
    println!("  prometheus page: {} lines, e.g.", page.lines().count());
    for line in shown {
        println!("    {line}");
    }

    println!("\n== failure modes & overload behavior ==");
    // The service sheds load instead of queueing it without bound. With
    // a global in-flight budget (CLI: `--max-inflight`; per-caller token
    // quotas via `--quota RATE[:BURST]`), `try_submit` answers
    // immediately: `Ok(ticket)` or a typed `OrderError::Rejected` whose
    // `retry_after_hint` sizes the backoff and whose `Rejection` hands
    // the request back untouched for a zero-clone retry. Deadlines
    // (`--deadline-ms`, `SubmitOptions::with_deadline_in`) ride with the
    // request and are checked at every stage boundary — preprocess,
    // reduce, cache probe, dispatch, and between elimination rounds — so
    // expired work resolves its ticket to `OrderError::DeadlineExceeded`
    // rather than burning a core. `wait_result()` surfaces all of this
    // as a `Result`; the plain `wait()` used above is the panicking
    // shim. Under `--shed-quality` the engine degrades quality before
    // availability: hybrid partitioning off, re-reduction sweeps off,
    // small components ordered inline by sequential AMD (each shed shows
    // up in the shard metrics and the request trace). Named failpoints
    // (`--failpoints`, env `PARAMD_FAILPOINTS`) inject panics, latency,
    // and verify-rejects at those same seams; the chaos suite uses them
    // to prove one poisoned request never wedges the service.
    let guarded = Service::new(1)
        .with_scheduler_threads(1)
        .with_queue_cap(4)
        .with_max_inflight(2);
    let big = paramd::matgen::mesh2d(60, 60);
    let mk = || OrderRequest {
        matrix: None,
        pattern: Some(big.clone()),
        method: Method::ParAmd {
            threads: 1,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    };
    let mut accepted = Vec::new();
    let mut shed = 0usize;
    for i in 0..6 {
        match guarded.try_submit(mk()) {
            Ok(t) => accepted.push((i, t)),
            Err(r) => {
                shed += 1;
                println!("  request {i}: {}", r.error);
            }
        }
    }
    println!("  burst of 6 under a 2-request budget: {} accepted, {shed} shed", accepted.len());
    for (i, t) in accepted {
        match t.wait_result() {
            Ok(rep) => println!("  request {i}: n={} {:.5}s", rep.perm.len(), rep.order_secs),
            Err(e) => println!("  request {i}: {e}"),
        }
    }
    // A deadline that has already lapsed never reaches a worker: the
    // first stage boundary resolves the ticket to the typed error.
    let doa = guarded.submit_opts(
        mk(),
        &SubmitOptions::default().with_deadline_in(std::time::Duration::ZERO),
    );
    match doa.wait_result() {
        Err(e) => println!("  dead-on-arrival deadline: {e}"),
        Ok(_) => println!("  (request beat its zero deadline)"),
    }
    // Quality shedding: with the threshold at 0 every request sheds, so
    // these four small components order inline — no jobs dispatched.
    let degraded = Service::new(1).with_shed_quality(true).with_shed_threshold(0);
    let rep = degraded.order(&OrderRequest {
        matrix: None,
        pattern: Some(paramd::matgen::multi_component(4, &[40, 60])),
        method: Method::ParAmd {
            threads: 2,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    });
    let dm = degraded.metrics();
    let jobs: u64 = dm.shards.per_shard.iter().map(|s| s.jobs).sum();
    println!(
        "  shed-quality: n={} ordered with {} sequential sheds, {jobs} shard jobs",
        rep.perm.len(),
        dm.shards.shed_sequential
    );
    let gm = guarded.metrics();
    println!(
        "  pipeline counters: rejected={} deadline_exceeded={}",
        gm.pipeline.rejected, gm.pipeline.deadline_exceeded
    );

    println!("\n== persistence: the result cache survives a restart ==");
    // With `with_persist` (CLI: `--persist-dir`, `--persist-max-mb`,
    // `--cache-ttl-secs`, `--cache-version`) every cache insert is also
    // appended — write-behind, one group-commit fsync per batch — to an
    // on-disk log of independently checksummed, length-prefixed record
    // frames. Reopening the directory replays snapshot → log: torn tail
    // writes are truncated (never replayed), corrupt records are
    // quarantined into a counted `recovery_rejects` bucket, and every
    // recovered entry is exact-verified against its stored CSR on first
    // hit. Records carry a version tag — bump `--cache-version` when
    // graph ids are reused with changed structure to invalidate the
    // whole store — and an optional TTL expires stale entries.
    let pdir = std::env::temp_dir().join(format!("paramd_demo_persist_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&pdir);
    let preq = OrderRequest {
        matrix: None,
        pattern: Some(paramd::matgen::mesh2d(50, 50)),
        method: Method::ParAmd {
            threads: 2,
            mult: 1.1,
            lim_total: 0,
        },
        compute_fill: false,
    };
    let persistent = Service::new(2).with_persist(&pdir).expect("persist dir must open");
    let cold = persistent.order(&preq);
    println!(
        "  cold order: n={} {:.5}s (written behind to {}/log.bin)",
        cold.perm.len(),
        cold.order_secs,
        pdir.display()
    );
    drop(persistent); // drains the dirty queue, fsyncs, joins the flusher

    let restarted = Service::new(2).with_persist(&pdir).expect("persist dir must reopen");
    let pm = restarted.metrics().shards.persist.expect("tier attached");
    println!(
        "  restart recovered {} entries / {} bytes (rejects={}, aborts={})",
        pm.warm_start_entries, pm.recovered_bytes, pm.recovery_rejects, pm.recovery_aborts
    );
    let warm = restarted.order(&preq);
    println!(
        "  warm order after restart: {:.5}s ({})",
        warm.order_secs,
        if restarted.metrics().cache.hits > 0 {
            "replayed from the recovered cache"
        } else {
            "recomputed"
        }
    );
    let _ = std::fs::remove_dir_all(&pdir);

    println!("\n== metrics ==\n{}", svc.metrics().report());
}
