//! Concurrent per-pivot elimination — the "core AMD" of Algorithm 3.3.
//!
//! A thread eliminates its pivots one at a time. Distance-2 independence
//! makes every structure it *writes* exclusively owned (see shared.rs and
//! DESIGN.md §6); the paper's §3.3.1 elbow-claim protocol is followed:
//! `L_me` is first collected into thread-local scratch, then exactly-sized
//! space is claimed with a single `fetch_add`, then the connection updates
//! are published.
//!
//! Each [`Outcome`] feeds the round-level telemetry: `Eliminated` masses
//! accumulate into the round's pivot/weight tallies and every `Deferred`
//! counts as one claim failure in the per-round
//! [`RoundSample`](crate::ordering::RoundSample) ring (the memory-contention
//! signal surfaced through `OrderingStats` and the service metrics).

use std::sync::atomic::Ordering::Relaxed;

use super::lists::{Affinity, ThreadLists};
use super::shared::{SharedGraph, ST_DEAD_ELEM, ST_DEAD_VAR, ST_ELEM, ST_VAR};
use super::workspace::Workspace;

/// Outcome of attempting to eliminate one pivot.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Outcome {
    /// Pivot eliminated; `mass` further columns went with it.
    Eliminated { mass: u32, merged: u32 },
    /// Elbow room exhausted; pivot left untouched (GC requested).
    Deferred,
}

/// Eliminate pivot `me` owned by this thread. `aggressive` enables
/// aggressive element absorption.
pub fn eliminate_pivot(
    g: &SharedGraph,
    ws: &mut Workspace,
    lists: &mut ThreadLists,
    aff: &Affinity,
    me: usize,
    aggressive: bool,
    work: &mut u64,
) -> Outcome {
    debug_assert_eq!(g.st(me), ST_VAR);
    let nv_me = g.nv_of(me);

    // ---- Phase 1a: collect L_me into scratch (reads only) ---------------
    let mark = ws.bump_epoch();
    ws.w[me] = mark;
    ws.lme.clear();
    let old_pe = g.pe_of(me);
    let old_elen = g.elen_of(me) as usize;
    let old_len = g.len_of(me) as usize;
    for k in old_elen..old_len {
        let v = g.iw_at(old_pe + k);
        let vu = v as usize;
        if g.st(vu) == ST_VAR && ws.w[vu] != mark {
            ws.w[vu] = mark;
            ws.lme.push(v);
        }
    }
    for k in 0..old_elen {
        let e = g.iw_at(old_pe + k) as usize;
        if g.st(e) != ST_ELEM {
            continue;
        }
        let ep = g.pe_of(e);
        for q in 0..g.len_of(e) as usize {
            let v = g.iw_at(ep + q);
            let vu = v as usize;
            if g.st(vu) == ST_VAR && ws.w[vu] != mark {
                ws.w[vu] = mark;
                ws.lme.push(v);
            }
        }
    }
    let lme_len = ws.lme.len();
    *work += (old_len + lme_len) as u64;

    // ---- Phase 1b: claim exactly |L_me| elbow slots (one fetch_add) -----
    let pme = match g.claim(lme_len) {
        Some(off) => off,
        None => return Outcome::Deferred,
    };
    for (k, &v) in ws.lme.iter().enumerate() {
        g.iw_set(pme + k, v);
    }

    // Publish me as an element; absorb its adjacent elements.
    for k in 0..old_elen {
        let e = g.iw_at(old_pe + k) as usize;
        if g.st(e) == ST_ELEM {
            g.set_st(e, ST_DEAD_ELEM);
            g.parent[e].store(me as i32, Relaxed);
        }
    }
    g.pe[me].store(pme, Relaxed);
    g.len[me].store(lme_len as i32, Relaxed);
    g.elen[me].store(0, Relaxed);
    g.set_st(me, ST_ELEM);
    g.nel.fetch_add(nv_me as usize, Relaxed);
    lists.remove(aff, me);

    // ---- Phase 2: Algorithm 2.1 pass 1 — thread-local w(e) weights ------
    for &vi in &ws.lme {
        let v = vi as usize;
        let p = g.pe_of(v);
        let elen_v = g.elen_of(v) as usize;
        *work += elen_v as u64;
        for q in 0..elen_v {
            let e = g.iw_at(p + q) as usize;
            if g.st(e) != ST_ELEM {
                continue;
            }
            if ws.w[e] >= mark {
                ws.w[e] -= g.nv_of(v) as u64;
            } else {
                ws.w[e] = mark + g.deg_of(e) as u64 - g.nv_of(v) as u64;
            }
        }
    }

    // ---- Phase 3: pass 2 — degree update, in-place rebuild, mass elim ---
    let mut mass: u32 = 0;
    let mut nvpiv = nv_me;
    ws.hash_scratch.clear();
    let lme = std::mem::take(&mut ws.lme);
    for &vi in &lme {
        let v = vi as usize;
        debug_assert_eq!(g.st(v), ST_VAR);
        let p = g.pe_of(v);
        let elen_v = g.elen_of(v) as usize;
        let len_v = g.len_of(v) as usize;
        *work += len_v as u64;

        let mut deg: i64 = 0;
        let mut hash: u64 = 0;
        let mut pn = p;
        for q in 0..elen_v {
            let e = g.iw_at(p + q) as usize;
            if g.st(e) != ST_ELEM {
                continue;
            }
            debug_assert!(ws.w[e] >= mark, "pass1 must have touched e");
            let dext = (ws.w[e] - mark) as i64;
            if dext > 0 || !aggressive {
                deg += dext;
                g.iw_set(pn, e as i32);
                pn += 1;
                hash = hash.wrapping_add(e as u64);
            } else {
                // Aggressive absorption: L_e ⊆ L_me ∪ {me}; every live
                // variable of L_e is owned by this thread (distance-2
                // argument), so the state flip cannot race with a reader.
                g.set_st(e, ST_DEAD_ELEM);
                g.parent[e].store(me as i32, Relaxed);
            }
        }
        let p3 = pn;
        for q in elen_v..len_v {
            let u = g.iw_at(p + q);
            let uu = u as usize;
            if g.st(uu) != ST_VAR || ws.w[uu] == mark {
                continue;
            }
            deg += g.nv_of(uu) as i64;
            g.iw_set(pn, u);
            pn += 1;
            hash = hash.wrapping_add(u as u64);
        }

        if deg == 0 && pn == p3 && aggressive {
            // Mass elimination: N_v ⊆ L_me ∪ {me}.
            g.set_st(v, ST_DEAD_VAR);
            g.parent[v].store(me as i32, Relaxed);
            let w = g.nv_of(v);
            nvpiv += w;
            g.nel.fetch_add(w as usize, Relaxed);
            g.nv[v].store(0, Relaxed);
            lists.remove(aff, v);
            mass += w as u32;
            continue;
        }
        // Splice me at the element/variable boundary (amd_2's relocation;
        // at least one entry was dropped, so the slot exists).
        debug_assert!(pn - p < len_v, "rebuild must shrink v's list");
        if pn > p3 {
            let first_var = g.iw_at(p3);
            g.iw_set(pn, first_var);
        }
        g.iw_set(p3, me as i32);
        pn += 1;
        hash = hash.wrapping_add(me as u64);
        g.elen[v].store((p3 - p + 1) as i32, Relaxed);
        g.len[v].store((pn - p) as i32, Relaxed);

        if deg == 0 && pn - p == 1 {
            // Non-aggressive-mode mass elimination (E_v = {me} only).
            g.set_st(v, ST_DEAD_VAR);
            g.parent[v].store(me as i32, Relaxed);
            let w = g.nv_of(v);
            nvpiv += w;
            g.nel.fetch_add(w as usize, Relaxed);
            g.nv[v].store(0, Relaxed);
            lists.remove(aff, v);
            mass += w as u32;
            continue;
        }

        // Partial degree; the |L_me \ v| term is added in Phase 5.
        let d = (g.deg_of(v) as i64).min(deg).max(0);
        g.degree[v].store(d as i32, Relaxed);
        ws.hash_scratch.push((hash, vi));
    }
    ws.lme = lme;

    // ---- Phase 4: supervariable detection (within L_me only) ------------
    let merged = detect_supervariables(g, ws, lists, aff, &mut nvpiv);

    // ---- Phase 5: compact L_me, final degrees, reinsert survivors -------
    let mut kept = 0usize;
    let mut degme_final = 0i32;
    let lme = std::mem::take(&mut ws.lme);
    for &vi in &lme {
        if g.st(vi as usize) == ST_VAR {
            g.iw_set(pme + kept, vi);
            kept += 1;
            degme_final += g.nv_of(vi as usize);
        }
    }
    g.len[me].store(kept as i32, Relaxed);
    g.degree[me].store(degme_final, Relaxed);
    g.nv[me].store(nvpiv, Relaxed);
    if kept == 0 {
        g.set_st(me, ST_DEAD_ELEM);
        g.parent[me].store(-1, Relaxed);
    }
    let nel_now = g.nel.load(Relaxed);
    for k in 0..kept {
        let v = g.iw_at(pme + k) as usize;
        let ext = (degme_final - g.nv_of(v)) as i64;
        // Weighted Ashcraft bound: remaining columns, not vertices (the
        // two differ when the reduction layer seeded `nv > 1`).
        let bound = g.weight as i64 - nel_now as i64 - g.nv_of(v) as i64;
        let d = (g.deg_of(v) as i64 + ext).min(bound).max(1) as usize;
        g.degree[v].store(d as i32, Relaxed);
        lists.insert(aff, v, d);
    }
    ws.lme = lme;
    *work += kept as u64;

    Outcome::Eliminated { mass, merged }
}

/// Hash-grouped exact-comparison supervariable merging among the pivot's
/// updated neighbors (`ws.hash_scratch` holds `(hash, v)` pairs).
///
/// Deliberately local: only variables inside this pivot's `L_me` are
/// compared, because those are the only ones this thread owns. Twins
/// that form *across* pivots (global twins) are merged by the round-
/// boundary re-reduction sweep (`ordering::reduce::live`), which runs
/// stop-the-world and therefore may compare arbitrary pairs.
fn detect_supervariables(
    g: &SharedGraph,
    ws: &mut Workspace,
    lists: &mut ThreadLists,
    aff: &Affinity,
    _nvpiv: &mut i32,
) -> u32 {
    let mut merged = 0u32;
    ws.hash_scratch.sort_unstable();
    let mut scratch = std::mem::take(&mut ws.hash_scratch);
    let mut i = 0;
    while i < scratch.len() {
        let mut j = i + 1;
        while j < scratch.len() && scratch[j].0 == scratch[i].0 {
            j += 1;
        }
        // Group [i, j) shares a hash; pairwise-compare.
        for a_idx in i..j {
            let a = scratch[a_idx].1 as usize;
            if g.st(a) != ST_VAR {
                continue;
            }
            for b_idx in a_idx + 1..j {
                let b = scratch[b_idx].1 as usize;
                if g.st(b) != ST_VAR {
                    continue;
                }
                if g.elen_of(a) == g.elen_of(b)
                    && g.len_of(a) == g.len_of(b)
                    && lists_identical(g, ws, a, b)
                {
                    // Merge b into a. Order matters for concurrent readers:
                    // grow a first, then kill b (over-count, never under-).
                    let w = g.nv_of(b);
                    g.nv[a].fetch_add(w, Relaxed);
                    g.nv[b].store(0, Relaxed);
                    g.set_st(b, ST_DEAD_VAR);
                    g.parent[b].store(a as i32, Relaxed);
                    lists.remove(aff, b);
                    merged += w as u32;
                }
            }
        }
        i = j;
    }
    scratch.clear();
    ws.hash_scratch = scratch;
    merged
}

/// Exact set comparison of two owned variables' lists via a fresh epoch.
fn lists_identical(g: &SharedGraph, ws: &mut Workspace, a: usize, b: usize) -> bool {
    let mark = ws.bump_epoch();
    let (pa, la) = (g.pe_of(a), g.len_of(a) as usize);
    for k in 0..la {
        ws.w[g.iw_at(pa + k) as usize] = mark;
    }
    let (pb, lb) = (g.pe_of(b), g.len_of(b) as usize);
    debug_assert_eq!(la, lb);
    (0..lb).all(|k| ws.w[g.iw_at(pb + k) as usize] == mark)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matgen::mesh2d;

    /// Single-threaded elimination through the concurrent structures must
    /// behave like the sequential engine: eliminate everything, produce a
    /// valid absorption forest.
    #[test]
    fn single_thread_full_elimination() {
        let g0 = mesh2d(6, 6);
        let g = SharedGraph::new(&g0, 1.5);
        let aff = Affinity::new(g0.n);
        let mut lists = ThreadLists::new(0, g0.n);
        for v in 0..g0.n {
            lists.insert(&aff, v, g0.degree(v));
        }
        let mut ws = Workspace::new(0, g0.n, 3);
        let mut work = 0u64;
        let mut elim_order = vec![];
        while g.nel.load(Relaxed) < g0.n {
            let d = lists.lamd(&aff);
            assert!(d < g0.n, "lists drained before all columns eliminated");
            let mut cand = vec![];
            lists.get(&aff, d, &mut cand);
            let me = cand[0] as usize;
            match eliminate_pivot(&g, &mut ws, &mut lists, &aff, me, true, &mut work) {
                Outcome::Eliminated { .. } => elim_order.push(me as i32),
                Outcome::Deferred => panic!("elbow 1.5 must suffice on a mesh"),
            }
        }
        assert_eq!(g.nel.load(Relaxed), g0.n);
        assert!(work > 0);
        // Every column is a pivot or transitively absorbed into one.
        let mut is_pivot = vec![false; g0.n];
        for &e in &elim_order {
            is_pivot[e as usize] = true;
        }
        for v in 0..g0.n {
            let mut x = v;
            let mut hops = 0;
            while !is_pivot[x] {
                let p = g.parent[x].load(Relaxed);
                assert!(p >= 0, "column {v} unaccounted");
                x = p as usize;
                hops += 1;
                assert!(hops <= g0.n);
            }
        }
    }

    #[test]
    fn deferral_on_zero_elbow() {
        let g0 = mesh2d(5, 5);
        let g = SharedGraph::new(&g0, 0.0);
        // Fill the (minimal) elbow so any claim fails.
        let avail = g.iw.len() - g.pfree.load(Relaxed);
        g.claim(avail).unwrap();
        let aff = Affinity::new(g0.n);
        let mut lists = ThreadLists::new(0, g0.n);
        for v in 0..g0.n {
            lists.insert(&aff, v, g0.degree(v));
        }
        let mut ws = Workspace::new(0, g0.n, 3);
        let mut work = 0u64;
        // Vertex 0 has neighbors, so its L_me claim must fail.
        assert_eq!(
            eliminate_pivot(&g, &mut ws, &mut lists, &aff, 0, true, &mut work),
            Outcome::Deferred
        );
        assert!(g.gc_requested.load(Relaxed));
        assert_eq!(g.st(0), ST_VAR, "deferred pivot must be untouched");
        assert_eq!(g.nel.load(Relaxed), 0);
    }

    #[test]
    fn mass_elimination_fires_on_cliques() {
        // K4: first pivot absorbs everything via mass elimination.
        let mut edges = vec![];
        for i in 0..4 {
            for j in i + 1..4 {
                edges.push((i, j));
            }
        }
        let g0 = crate::graph::csr::SymGraph::from_edges(4, &edges);
        let g = SharedGraph::new(&g0, 1.5);
        let aff = Affinity::new(4);
        let mut lists = ThreadLists::new(0, 4);
        for v in 0..4 {
            lists.insert(&aff, v, g0.degree(v));
        }
        let mut ws = Workspace::new(0, 4, 1);
        let mut work = 0;
        // K4 \ {0} is a clique covered entirely by the new element, so all
        // three neighbors mass-eliminate together with the pivot.
        match eliminate_pivot(&g, &mut ws, &mut lists, &aff, 0, true, &mut work) {
            Outcome::Eliminated { mass, merged } => {
                assert_eq!(mass, 3);
                assert_eq!(merged, 0);
            }
            o => panic!("unexpected outcome {o:?}"),
        }
        assert_eq!(g.nel.load(Relaxed), 4);
    }
}
