//! Failure injection: elbow exhaustion and GC paths, indefinite matrices,
//! missing artifacts, malformed inputs.

use paramd::cholesky::{factor, DenseTail, NativeDense};
use paramd::graph::csr::CsrMatrix;
use paramd::matgen::{mesh2d, spd_from_graph};
use paramd::ordering::{amd_seq::AmdSeq, paramd::ParAmd, Ordering as _};

#[test]
fn paramd_small_elbow_survives_with_gc() {
    let g = mesh2d(28, 28);
    let r = ParAmd::new(2).with_elbow(0.35).order(&g);
    assert!(r.stats.gc_count > 0, "expected GC under elbow pressure");
    assert!(paramd::graph::perm::is_valid_perm(&r.perm));
}

#[test]
#[should_panic(expected = "ParAMD stalled")]
fn paramd_hopeless_elbow_poisons_cleanly() {
    // K40 with zero elbow: the first element list needs 39 slots but only
    // the 16-word constant slack exists, and GC can reclaim nothing (no
    // dead entries). The poison protocol must bring every thread down
    // without deadlocking at a barrier.
    let mut edges = vec![];
    for i in 0..40usize {
        for j in i + 1..40 {
            edges.push((i, j));
        }
    }
    let g = paramd::graph::csr::SymGraph::from_edges(40, &edges);
    let _ = ParAmd::new(3).with_elbow(0.0).order(&g);
}

#[test]
fn amd_seq_tiny_elbow_gc_matches_default_quality() {
    let g = mesh2d(30, 30);
    let tight = AmdSeq {
        elbow: 0.02,
        ..Default::default()
    };
    let r1 = tight.order(&g);
    let r2 = AmdSeq::default().order(&g);
    assert!(r1.stats.gc_count > 0);
    let f1 = paramd::symbolic::fill_in(&g, &r1.perm);
    let f2 = paramd::symbolic::fill_in(&g, &r2.perm);
    // Same algorithm; GC must not change the ordering at all.
    assert_eq!(f1, f2, "GC perturbed the elimination");
}

#[test]
fn indefinite_matrix_rejected_with_column_info() {
    let trip: Vec<(usize, usize, f64)> = (0..6).map(|i| (i, i, -2.0)).collect();
    let a = CsrMatrix::from_triplets(6, 6, &trip);
    let id: Vec<i32> = (0..6).collect();
    let err = factor(&a, &id, DenseTail::None, &NativeDense)
        .err()
        .expect("indefinite matrix must be rejected");
    assert!(err.contains("not positive definite"), "{err}");
}

#[test]
fn indefinite_in_dense_tail_rejected() {
    // SPD leading block, indefinite tail: the dense engine must flag it.
    let mut trip: Vec<(usize, usize, f64)> = (0..20).map(|i| (i, i, 4.0)).collect();
    trip.push((19, 19, -8.0)); // sums to -4 on the last diagonal
    let a = CsrMatrix::from_triplets(20, 20, &trip);
    let id: Vec<i32> = (0..20).collect();
    let err = factor(&a, &id, DenseTail::Fixed(8), &NativeDense)
        .err()
        .expect("indefinite tail must be rejected");
    assert!(err.contains("not positive definite"), "{err}");
}

#[test]
fn runtime_missing_artifacts_errors_cleanly() {
    let err = paramd::runtime::PjrtEngine::load_dir(std::path::Path::new("/nonexistent/dir"))
        .err()
        .expect("must fail");
    let msg = format!("{err:#}");
    assert!(msg.contains("make artifacts"), "{msg}");
}

#[test]
fn mm_reader_rejects_truncated_file() {
    let dir = std::env::temp_dir().join("paramd_failinj");
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("trunc.mtx");
    std::fs::write(&p, "%%MatrixMarket matrix coordinate real general\n5 5 3\n1 1 1.0\n").unwrap();
    assert!(paramd::graph::mm::read_matrix_market(&p).is_err());
}

#[test]
fn solver_handles_singleton_and_diagonal_systems() {
    // 1x1
    let a = CsrMatrix::from_triplets(1, 1, &[(0, 0, 4.0)]);
    let f = factor(&a, &[0], DenseTail::None, &NativeDense).unwrap();
    let x = paramd::cholesky::solve(&f, &[8.0]);
    assert!((x[0] - 2.0).abs() < 1e-14);
    // Pure diagonal
    let trip: Vec<(usize, usize, f64)> = (0..9).map(|i| (i, i, (i + 1) as f64)).collect();
    let a = CsrMatrix::from_triplets(9, 9, &trip);
    let g = paramd::graph::symmetrize(&a);
    let perm = AmdSeq::default().order(&g).perm;
    let f = factor(&a, &perm, DenseTail::default(), &NativeDense).unwrap();
    let b: Vec<f64> = (0..9).map(|i| (i + 1) as f64).collect();
    let x = paramd::cholesky::solve(&f, &b);
    for xi in x {
        assert!((xi - 1.0).abs() < 1e-12);
    }
}

#[test]
fn spd_with_huge_value_spread_still_solves() {
    let g = mesh2d(8, 8);
    let mut a = spd_from_graph(&g, 1.0);
    // Scale one row/col pair by 1e8 (keeps symmetry + SPD).
    for p in 0..a.nnz() {
        let r = a
            .rowptr
            .iter()
            .position(|&rp| rp > p)
            .unwrap()
            - 1;
        if r == 5 || a.colind[p] == 5 {
            a.values[p] *= 1e8;
        }
        if r == 5 && a.colind[p] == 5 {
            a.values[p] *= 1e8; // diagonal gets both factors
        }
    }
    let gs = paramd::graph::symmetrize(&a);
    let perm = AmdSeq::default().order(&gs).perm;
    let f = factor(&a, &perm, DenseTail::None, &NativeDense).unwrap();
    let b = vec![1.0; a.nrows];
    let x = paramd::cholesky::solve(&f, &b);
    assert!(paramd::cholesky::residual(&a, &x, &b) < 1e-8);
}
