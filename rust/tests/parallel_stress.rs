//! Concurrency stress for ParAMD: odd thread counts, more threads than
//! vertices, repeated seeds, and cross-thread-count quality stability.

use paramd::graph::csr::SymGraph;
use paramd::graph::perm::is_valid_perm;
use paramd::matgen::{kkt, mesh2d, mesh3d, random_graph};
use paramd::ordering::{amd_seq::AmdSeq, paramd::ParAmd, Ordering as _};
use paramd::symbolic::fill_in;

#[test]
fn thread_sweep_on_mesh() {
    let g = mesh2d(18, 18);
    for t in [1, 2, 3, 5, 7, 8, 13, 16] {
        let r = ParAmd::new(t).order(&g);
        assert!(is_valid_perm(&r.perm), "t={t}");
        assert_eq!(r.perm.len(), g.n);
    }
}

#[test]
fn more_threads_than_vertices() {
    let g = random_graph(20, 3, 1);
    let r = ParAmd::new(64).order(&g);
    assert!(is_valid_perm(&r.perm));
}

#[test]
fn single_vertex_and_edge() {
    for (n, edges) in [(1usize, vec![]), (2, vec![(0usize, 1usize)])] {
        let g = SymGraph::from_edges(n, &edges);
        let r = ParAmd::new(4).order(&g);
        assert!(is_valid_perm(&r.perm));
    }
}

#[test]
fn repeated_runs_all_valid_and_quality_stable() {
    let g = mesh3d(8, 8, 8);
    let f_seq = fill_in(&g, &AmdSeq::default().order(&g).perm) as f64;
    for seed in 0..6 {
        let r = ParAmd::new(4).with_seed(seed).order(&g);
        assert!(is_valid_perm(&r.perm), "seed={seed}");
        let f = fill_in(&g, &r.perm) as f64;
        assert!(
            f < 1.8 * f_seq,
            "seed={seed}: fill {f} vs seq {f_seq} drifted"
        );
    }
}

#[test]
fn quality_stable_across_thread_counts() {
    let g = kkt(8, 8, 8, 3, 5);
    let fills: Vec<f64> = [1usize, 2, 4, 8]
        .iter()
        .map(|&t| fill_in(&g, &ParAmd::new(t).order(&g).perm) as f64)
        .collect();
    let lo = fills.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = fills.iter().cloned().fold(0.0, f64::max);
    assert!(
        hi / lo < 1.5,
        "fill varies too much across threads: {fills:?}"
    );
}

#[test]
fn dist2_property_spot_check_on_first_round() {
    // Run with a tracing seed and assert the first-round pivot set is
    // distance-2 independent in the *original* graph (where quotient
    // neighborhoods equal graph neighborhoods).
    let g = mesh2d(14, 14);
    let (r, d) = ParAmd::new(4).order_detailed(&g);
    assert!(is_valid_perm(&r.perm));
    let first_round_size = d.set_sizes.first().copied().unwrap_or(0) as usize;
    assert!(first_round_size >= 1);
    // The first `first_round_size` pivots of the elimination order are the
    // round-0 set (merged in round order).
    let pivots: Vec<usize> = r
        .perm
        .iter()
        .map(|&v| v as usize)
        .take(1) // perm order within bucket starts with the pivot itself
        .collect();
    // Cheap sanity only: the first pivot must exist; the strong D2 check
    // lives in the dist2 unit tests.
    assert!(pivots[0] < g.n);
}

#[test]
fn stress_many_small_graphs_concurrently() {
    // Drive several ParAMD instances from parallel test threads to shake
    // out accidental global state.
    std::thread::scope(|s| {
        for seed in 0..4u64 {
            s.spawn(move || {
                let g = random_graph(150, 5, seed);
                let r = ParAmd::new(3).with_seed(seed).order(&g);
                assert!(is_valid_perm(&r.perm));
            });
        }
    });
}

#[test]
fn huge_lim_and_tiny_lim_both_work() {
    let g = mesh2d(16, 16);
    for lim in [1usize, 2, usize::MAX / 4] {
        let r = ParAmd::new(2).with_lim_total(lim).order(&g);
        assert!(is_valid_perm(&r.perm), "lim={lim}");
    }
}

#[test]
fn non_aggressive_parallel_mode() {
    let g = mesh3d(6, 6, 6);
    let mut cfg = ParAmd::new(4);
    cfg.aggressive = false;
    let r = cfg.order(&g);
    assert!(is_valid_perm(&r.perm));
}
