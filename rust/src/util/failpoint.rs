//! Named **failpoints**: runtime-armed fault injection for the serving
//! stack.
//!
//! A failpoint is a named hook compiled into a failure-critical site —
//! the pipeline scheduler, the shard dispatcher, the arena checkout, the
//! result-cache verify — that does nothing until armed. Armed, it
//! performs a configured [`FailAction`] (panic, injected latency, forced
//! verify-reject) for a bounded number of firings, letting the chaos
//! suite prove that one poisoned request never wedges the service, leaks
//! an arena, or corrupts a later permutation.
//!
//! The disarmed fast path is a single relaxed atomic load, so the hooks
//! are free in production. Arm programmatically ([`arm`]/[`arm_spec`]),
//! from the CLI (`serve --failpoints`), or from the environment
//! (`PARAMD_FAILPOINTS`, read by the binary at startup) with the grammar
//!
//! ```text
//! name=action[*count][,name=action[*count]...]
//! action := panic | reject | sleep:<millis>
//! ```
//!
//! e.g. `shard-dispatch=panic*1,stage-latency=sleep:30`. Without `*N`
//! the point fires every time until [`disarm_all`]. Firings are counted
//! per point ([`fired`]) so tests can assert a fault actually happened.
//!
//! The registry is process-global: tests that arm failpoints must
//! serialize themselves (the chaos suite does) and use the real site
//! names only in their own test binary.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering::Relaxed};
use std::sync::{Mutex, OnceLock};
use std::time::Duration;

use super::lock_unpoisoned;

/// Site: the pipeline scheduler, just before a request is processed.
pub const SCHEDULER_PANIC: &str = "pipeline-scheduler";
/// Site: a shard dispatcher, just before elimination starts.
pub const DISPATCHER_PANIC: &str = "shard-dispatch";
/// Site: the arena-pool checkout (simulated allocation failure).
pub const ARENA_CHECKOUT: &str = "arena-checkout";
/// Site: the pipeline's order stage (inject latency with `sleep:<ms>`).
pub const STAGE_LATENCY: &str = "stage-latency";
/// Site: the result cache's exact-verify compare (`reject` forces a
/// verify-reject, downgrading a hit to a miss).
pub const CACHE_VERIFY: &str = "cache-verify";
/// Site: the persist flusher, between writing a record's frame header
/// and its payload — `panic` here leaves a torn tail on disk, exactly
/// the shape recovery must truncate.
pub const PERSIST_APPEND: &str = "persist-append";
/// Site: the persist flusher, just before the group-commit fsync
/// (`sleep:<ms>` holds the window open for kill -9 crash tests).
pub const PERSIST_FSYNC: &str = "persist-fsync";
/// Site: snapshot compaction, after writing the temp snapshot but
/// before the atomic rename that publishes it.
pub const PERSIST_SNAPSHOT: &str = "persist-snapshot";
/// Site: recovery-on-open, before the snapshot→log replay begins.
pub const PERSIST_RECOVER: &str = "persist-recover";

/// What an armed failpoint does when its site is hit.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailAction {
    /// Panic with `failpoint <name> fired` — exercises the
    /// `catch_unwind` containment around the site.
    Panic,
    /// Sleep for the duration (injected stage latency).
    Sleep(Duration),
    /// Make [`should_reject`] report `true` at the site (e.g. force the
    /// cache's exact-verify to fail).
    Reject,
}

struct FailPoint {
    action: FailAction,
    /// Remaining firings; `None` = unlimited, `Some(0)` = exhausted
    /// (kept resident so [`fired`] still reports its count).
    remaining: Option<u64>,
    fired: u64,
}

/// Disarmed fast path: one relaxed load, no lock.
static ARMED: AtomicBool = AtomicBool::new(false);

fn registry() -> &'static Mutex<HashMap<String, FailPoint>> {
    static REG: OnceLock<Mutex<HashMap<String, FailPoint>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Arm `name` with `action`, firing at most `limit` times (`None` =
/// until [`disarm_all`]). Re-arming an exhausted or active point resets
/// its budget but keeps its fired count.
pub fn arm(name: &str, action: FailAction, limit: Option<u64>) {
    let mut reg = lock_unpoisoned(registry().lock());
    let fired = reg.get(name).map_or(0, |p| p.fired);
    reg.insert(
        name.to_string(),
        FailPoint {
            action,
            remaining: limit,
            fired,
        },
    );
    ARMED.store(true, Relaxed);
}

/// Disarm everything and clear fired counts.
pub fn disarm_all() {
    lock_unpoisoned(registry().lock()).clear();
    ARMED.store(false, Relaxed);
}

/// Times `name` has actually fired (0 if never armed).
pub fn fired(name: &str) -> u64 {
    lock_unpoisoned(registry().lock()).get(name).map_or(0, |p| p.fired)
}

/// Parse and arm a `name=action[*count],...` schedule; returns how many
/// points were armed or a message describing the malformed entry.
pub fn arm_spec(spec: &str) -> Result<usize, String> {
    let mut armed = 0usize;
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let (name, rest) = entry
            .split_once('=')
            .ok_or_else(|| format!("failpoint entry '{entry}' is missing '='"))?;
        let (action_str, limit) = match rest.split_once('*') {
            Some((a, n)) => {
                let n: u64 = n
                    .parse()
                    .map_err(|_| format!("failpoint '{name}': bad count '{n}'"))?;
                (a, Some(n))
            }
            None => (rest, None),
        };
        let action = match action_str {
            "panic" => FailAction::Panic,
            "reject" => FailAction::Reject,
            _ => match action_str.strip_prefix("sleep:") {
                Some(ms) => {
                    let ms: u64 = ms
                        .parse()
                        .map_err(|_| format!("failpoint '{name}': bad sleep '{ms}'"))?;
                    FailAction::Sleep(Duration::from_millis(ms))
                }
                None => {
                    return Err(format!(
                        "failpoint '{name}': unknown action '{action_str}' \
                         (expected panic | reject | sleep:<ms>)"
                    ))
                }
            },
        };
        arm(name.trim(), action, limit);
        armed += 1;
    }
    Ok(armed)
}

/// Arm from the `PARAMD_FAILPOINTS` environment variable if set; returns
/// how many points were armed.
pub fn arm_from_env() -> Result<usize, String> {
    match std::env::var("PARAMD_FAILPOINTS") {
        Ok(spec) if !spec.is_empty() => arm_spec(&spec),
        _ => Ok(0),
    }
}

/// Consume one firing of `name` if armed with budget left.
fn take(name: &str) -> Option<FailAction> {
    let mut reg = lock_unpoisoned(registry().lock());
    let p = reg.get_mut(name)?;
    match p.remaining {
        Some(0) => return None,
        Some(ref mut n) => *n -= 1,
        None => {}
    }
    p.fired += 1;
    Some(p.action)
}

/// The site hook: no-op while disarmed; otherwise perform the armed
/// action (`Panic` panics, `Sleep` sleeps, `Reject` is a no-op here —
/// sites that can reject consult [`should_reject`] instead).
#[inline]
pub fn hit(name: &str) {
    if !ARMED.load(Relaxed) {
        return;
    }
    match take(name) {
        Some(FailAction::Panic) => panic!("failpoint {name} fired"),
        Some(FailAction::Sleep(d)) => std::thread::sleep(d),
        Some(FailAction::Reject) | None => {}
    }
}

/// Site hook for reject-capable sites: `true` exactly when `name` is
/// armed with [`FailAction::Reject`] and has budget left (consumes one
/// firing).
#[inline]
pub fn should_reject(name: &str) -> bool {
    if !ARMED.load(Relaxed) {
        return false;
    }
    match take(name) {
        Some(FailAction::Reject) => true,
        Some(FailAction::Panic) => panic!("failpoint {name} fired"),
        Some(FailAction::Sleep(d)) => {
            std::thread::sleep(d);
            false
        }
        None => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The registry is process-global and unit tests run concurrently:
    // serialize this module's tests and use names no production site
    // consults, so arming here can never poison a neighboring test's
    // service.
    fn serial() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        lock_unpoisoned(GATE.lock())
    }

    #[test]
    fn disarmed_points_are_free_and_silent() {
        let _g = serial();
        hit("test-fp-never-armed");
        assert!(!should_reject("test-fp-never-armed"));
        assert_eq!(fired("test-fp-never-armed"), 0);
    }

    #[test]
    fn limited_point_fires_exactly_n_times() {
        let _g = serial();
        arm("test-fp-limit", FailAction::Reject, Some(2));
        assert!(should_reject("test-fp-limit"));
        assert!(should_reject("test-fp-limit"));
        assert!(!should_reject("test-fp-limit"), "budget exhausted");
        assert_eq!(fired("test-fp-limit"), 2);
        disarm_all();
    }

    #[test]
    fn panic_action_panics_with_the_point_name() {
        let _g = serial();
        arm("test-fp-panic", FailAction::Panic, Some(1));
        let caught = std::panic::catch_unwind(|| hit("test-fp-panic"));
        let msg = crate::util::panic_message(caught.expect_err("must panic").as_ref());
        assert!(msg.contains("failpoint test-fp-panic fired"), "{msg}");
        hit("test-fp-panic"); // exhausted: silent
        assert_eq!(fired("test-fp-panic"), 1);
        disarm_all();
    }

    #[test]
    fn spec_grammar_parses_and_rejects_malformed_entries() {
        let _g = serial();
        let n = arm_spec("test-fp-a=panic*1, test-fp-b=sleep:5, test-fp-c=reject").unwrap();
        assert_eq!(n, 3);
        let t0 = std::time::Instant::now();
        hit("test-fp-b");
        assert!(t0.elapsed() >= Duration::from_millis(5), "sleep action waits");
        assert!(should_reject("test-fp-c"));
        disarm_all();

        assert!(arm_spec("no-equals").is_err());
        assert!(arm_spec("x=explode").is_err());
        assert!(arm_spec("x=sleep:abc").is_err());
        assert!(arm_spec("x=panic*z").is_err());
        disarm_all();
    }
}
